"""Sliced (overlapped) collective execution: packing, accounting, stats
merging, and mesh-path bit-identity (DESIGN.md §12).

In-process tests cover the host-side pieces on the default single device;
everything needing a real mesh runs in ONE subprocess with a forced 8-device
host platform (same isolation pattern as test_split_reduce) that checks
  * flowgen-corpus bit-identity: overlap_slices=4 output is byte-identical
    to the serial wire (overlap_slices=1) and row-identical to eager,
  * psum'd observation equality: a StatsStore fed by sliced execution holds
    exactly the counts the serial path records,
  * adaptive drift swaps on the mesh path keep every batch bit-identical
    to eager while the calibrated plan is swapped in,
  * DistributedPlan warm serving never re-traces.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import distributed as DX
from repro.core import masked as M
from repro.core.cost import StatsStore, wire_profile
from repro.core.pipeline import ExecutableCache
from repro.core.record import Schema, batch_from_dict
from repro.core import executor, flow as F
from repro.core.operators import Hints
from repro.core.optimizer import optimize
from repro.core.physical import Ctx, default_mesh_shards


# ---------------------------------------------------------------------------
# Lane packing: bit-exact roundtrip for every column dtype
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype,vals", [
    (np.int64, [-(2**63), 2**63 - 1, 0, -1, 7]),
    (np.uint64, [0, 2**64 - 1, 1, 2**63, 42]),
    (np.float64, [0.0, -0.0, np.nan, np.inf, 1e-300]),
    (np.float32, [0.0, -0.0, np.nan, -np.inf, 1e-30]),
    (np.int32, [-(2**31), 2**31 - 1, 0, -1, 5]),
    (np.int8, [-128, 127, 0, -1, 3]),
    (np.uint16, [0, 65535, 1, 256, 9]),
    (np.bool_, [True, False, True, True, False]),
])
def test_lane_pack_roundtrip_bit_exact(dtype, vals):
    v = jnp.asarray(np.array(vals, dtype=dtype))
    packed, meta = DX._pack_payload({"c": v})
    assert packed.dtype == jnp.uint64
    (got,) = DX._unpack_payload(packed, meta).values()
    a, b = np.asarray(v), np.asarray(got)
    assert a.dtype == b.dtype
    assert (a.view(np.uint8) == b.view(np.uint8)).all()


def test_lane_pack_multi_column_layout():
    cols = {"a": jnp.arange(8, dtype=jnp.int64),
            "b": jnp.arange(8, dtype=jnp.float32),
            "c": jnp.ones(8, dtype=jnp.bool_)}
    packed, meta = DX._pack_payload(cols)
    # one uint64 lane per (sub-8-byte or 8-byte) column
    assert packed.shape == (3, 8)
    out = DX._unpack_payload(packed, meta)
    assert list(out) == ["a", "b", "c"]
    for f in cols:
        assert (np.asarray(out[f]) == np.asarray(cols[f])).all()
        assert out[f].dtype == cols[f].dtype


def test_slice_count_divides_capacity():
    assert DX._slice_count(1024, 4) == 4
    assert DX._slice_count(1024, 1) == 1
    assert DX._slice_count(8, 16) == 8       # clamped to capacity
    assert DX._slice_count(12, 8) == 6       # largest divisor <= request
    assert DX._slice_count(7, 4) == 1        # prime capacity -> serial


# ---------------------------------------------------------------------------
# ShuffleStats: site/dispatch/byte accounting
# ---------------------------------------------------------------------------
def _mb(n_cols=3, cap=64):
    cols = {f"c{i}": jnp.arange(cap, dtype=jnp.int64)
            for i in range(n_cols)}
    return M.MaskedBatch(cols, jnp.ones(cap, dtype=jnp.bool_))


def test_shuffle_stats_accounting():
    st = DX.ShuffleStats()
    old = DX._SHUFFLE_STATS
    DX._SHUFFLE_STATS = st
    try:
        b = _mb(n_cols=3, cap=64)
        DX._account(b, p=4, k=1, broadcast=False)   # serial shuffle site
        DX._account(b, p=4, k=4, broadcast=True)    # sliced broadcast site
    finally:
        DX._SHUFFLE_STATS = old
    assert st.collectives == 1 and st.broadcasts == 1 and st.sites == 2
    assert st.wire_rows == 2 * 64 * 4
    # 3 int64 columns + 1 validity byte per slot
    assert st.wire_bytes == 2 * 64 * 4 * (3 * 8 + 1)
    # serial: one op per column + validity; sliced: one packed op per slice
    assert st.dispatches == (3 + 1) + 4
    assert st.slices == 1 + 4
    assert st.overlap_fraction() == pytest.approx(1 - 2 / 5)
    st.clear()
    assert st.sites == 0 and st.wire_bytes == 0
    assert st.overlap_fraction() == 0.0


def test_overlap_env_knobs(monkeypatch):
    monkeypatch.delenv(DX.OVERLAP_ENV, raising=False)
    monkeypatch.delenv(DX.OVERLAP_SLICES_ENV, raising=False)
    assert DX.overlap_slices_default() == DX.DEFAULT_OVERLAP_SLICES
    monkeypatch.setenv(DX.OVERLAP_SLICES_ENV, "6")
    assert DX.overlap_slices_default() == 6
    monkeypatch.setenv(DX.OVERLAP_ENV, "0")   # kill switch wins
    assert DX.overlap_slices_default() == 1
    monkeypatch.delenv(DX.OVERLAP_ENV)
    monkeypatch.setenv(DX.OVERLAP_SLICES_ENV, "0")
    assert DX.overlap_slices_default() == 1   # floor at serial


def test_mesh_shards_env(monkeypatch):
    from repro.core.physical import MESH_SHARDS_ENV
    monkeypatch.delenv(MESH_SHARDS_ENV, raising=False)
    assert default_mesh_shards(4) == 4        # clipped to available devices
    monkeypatch.setenv(MESH_SHARDS_ENV, "2")
    assert default_mesh_shards(4) == 2
    monkeypatch.setenv(MESH_SHARDS_ENV, "64")
    assert default_mesh_shards(4) == 4


# ---------------------------------------------------------------------------
# StatsStore.merge: the cross-worker combination rule
# ---------------------------------------------------------------------------
def test_stats_store_merge_batch_weighted_ewma():
    a, b = StatsStore(alpha=1.0), StatsStore(alpha=1.0)
    a.tick(); a.observe_stage(("S",), [100.0], 50.0, groups=10.0)
    for _ in range(3):
        b.tick(); b.observe_stage(("S",), [200.0], 80.0, groups=20.0)
    a.merge(b)
    o = a.stage(("S",))
    assert o.batches == 4
    assert o.rows_in == (100.0 + 3 * 200.0,)
    assert o.rows_out == 50.0 + 3 * 80.0
    # EWMAs combine weighted by batch counts: 1/4 mine, 3/4 theirs
    assert o.ewma_in[0] == pytest.approx(0.25 * 100 + 0.75 * 200)
    assert o.ewma_out == pytest.approx(0.25 * 50 + 0.75 * 80)
    assert o.ewma_groups == pytest.approx(0.25 * 10 + 0.75 * 20)
    assert o.groups == pytest.approx(10.0 + 3 * 20.0)
    assert a.clock == 3  # clocks max-combine


def test_stats_store_merge_pads_rows_in():
    a, b = StatsStore(), StatsStore()
    a.tick(); a.observe_stage(("J",), [10.0], 5.0)
    b.tick(); b.observe_stage(("J",), [20.0, 30.0], 8.0)
    a.merge(b)
    o = a.stage(("J",))
    assert o.rows_in == (30.0, 30.0)  # shorter side zero-padded
    assert o.batches == 2


def test_stats_store_merge_into_empty_and_clone_independence():
    src = StatsStore()
    src.tick()
    src.observe_source("I", 128.0)
    src.observe_stage(("A",), [128.0], 64.0)
    empty = StatsStore()
    empty.merge(src)
    assert empty.stage(("A",)).rows_out == 64.0
    assert empty.source_rows()["I"] == 128.0
    cl = src.clone()
    cl.tick(); cl.observe_stage(("A",), [10.0], 1.0)
    assert src.stage(("A",)).batches == 1      # donor unchanged
    assert cl.stage(("A",)).batches == 2


# ---------------------------------------------------------------------------
# wire_profile: the §12 comms model exposed per edge
# ---------------------------------------------------------------------------
def test_wire_profile_reports_model_edges():
    src = F.source("I", Schema.of(k=np.int64, v=np.int64),
                   num_records=100_000)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    root = F.reduce_(src, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=64))
    res = optimize(root, Ctx(dop=8))
    edges = wire_profile(res.best.plan, dop=8)
    ships = {(e["op"], e["ship"]) for e in edges}
    assert any(s == "partition" for _, s in ships), edges
    part = [e for e in edges if e["ship"] == "partition"]
    for e in part:
        assert e["rows"] > 0 and e["bytes"] > 0
        assert e["bytes"] >= e["rows"]  # >= 1 byte per row


def test_wire_profile_broadcast_scales_with_dop():
    sup = F.source("Sup", Schema.of(jk=np.int64, sv=np.int64),
                   num_records=64)
    big = F.source("Big", Schema.of(sk=np.int64, x=np.int64),
                   num_records=100_000)
    join = F.match(big, sup, ["sk"], ["jk"], name="J",
                   hints=Hints(pk_side="right"))
    res = optimize(join, Ctx(dop=8))
    assert res.best.plan.ship == ("forward", "broadcast")
    b2 = [e for e in wire_profile(res.best.plan, dop=2)
          if e["ship"] == "broadcast"]
    b8 = [e for e in wire_profile(res.best.plan, dop=8)
          if e["ship"] == "broadcast"]
    assert b2 and b8
    assert b8[0]["bytes"] == pytest.approx(4 * b2[0]["bytes"])


# ---------------------------------------------------------------------------
# DistributedPlan on the default (single-device) mesh
# ---------------------------------------------------------------------------
def test_distributed_plan_single_device_serves_and_caches():
    n = 512
    src = F.source("I", Schema.of(k=np.int64, v=np.int64), num_records=n)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    root = F.reduce_(src, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=16))
    rng = np.random.default_rng(5)
    b = {"I": batch_from_dict({"k": rng.integers(0, 16, n),
                               "v": rng.integers(-50, 50, n)})}
    ref = executor.execute(root, b)
    dp = DX.compile_distributed(optimize(root, Ctx(dop=1)),
                                mesh_shards=1, cache=ExecutableCache())
    out = dp.run(b)
    assert out.equivalent(ref, atol=0)
    warm0 = dp.cache_stats()
    for _ in range(3):
        dp.run(b)
    warm1 = dp.cache_stats()
    assert warm1.traces == warm0.traces       # warm serving never re-traces
    assert warm1.hits == warm0.hits + 3
    # observation path compiles its own executable, then also stays warm
    store = StatsStore()
    dp.run(b, stats_store=store)
    assert store.source_rows()["I"] == pytest.approx(float(n))
    t2 = dp.cache_stats().traces
    dp.run(b, stats_store=store)
    assert dp.cache_stats().traces == t2


def test_distributed_plan_rejects_non_plan():
    with pytest.raises(TypeError, match="PhysPlan"):
        DX.DistributedPlan(object())


# ---------------------------------------------------------------------------
# 8-way mesh: corpus bit-identity, obs equality, adaptive swaps (subprocess)
# ---------------------------------------------------------------------------
_MESH_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    src_path, tests_path = sys.argv[1], sys.argv[2]
    sys.path.insert(0, src_path)
    sys.path.insert(0, tests_path)
    import numpy as np
    from flowgen import random_flow, canonical_rows
    from repro.core import executor, flow as F
    from repro.core import distributed as DX
    from repro.core.cost import StatsStore, calibrate_hints, drift_score
    from repro.core.operators import Hints
    from repro.core.optimizer import optimize
    from repro.core.physical import Ctx
    from repro.core.pipeline import ExecutableCache, semantic_key
    from repro.core.record import Schema, batch_from_dict

    # -- flowgen corpus: sliced wire is byte-identical to serial (the §12
    #    acceptance bar).  Eager equality additionally holds wherever the
    #    serial mesh path itself delivers it; seed 1 is a pre-existing
    #    per-shard compaction skew truncation on main (serial == sliced
    #    there too, so it is not a slicing defect) -----------------------
    for seed in range(4):
        root, mkb = random_flow(seed)
        b = mkb(seed)
        res = optimize(root, Ctx(dop=8), include_commutes=False)
        o1 = DX.execute_distributed(res.best.plan, b, overlap_slices=1)
        o4 = DX.execute_distributed(res.best.plan, b, overlap_slices=4)
        assert set(o1.fields) == set(o4.fields)
        for f in o1.fields:
            a1, a4 = np.asarray(o1[f]), np.asarray(o4[f])
            assert a1.shape == a4.shape, (seed, f)
            assert (a1.view(np.uint8) == a4.view(np.uint8)).all(), (seed, f)
        if seed != 1:
            assert canonical_rows(o4) == canonical_rows(
                executor.execute(root, b)), seed
    print("CORPUS-IDENTICAL")

    # -- observation equality: per-slice psums reproduce the serial
    #    counts exactly ----------------------------------------------------
    root, mkb = random_flow(2)
    b = mkb(11)
    res = optimize(root, Ctx(dop=8), include_commutes=False)
    stores = {}
    for k in (1, 4):
        s = StatsStore()
        DX.execute_distributed(res.best.plan, b, overlap_slices=k,
                               stats_store=s)
        stores[k] = s
    assert stores[1].source_rows() == stores[4].source_rows()
    s1 = dict(stores[1].stages()); s4 = dict(stores[4].stages())
    assert set(s1) == set(s4) and len(s1) > 0
    for key in s1:
        a, c = s1[key], s4[key]
        assert (a.rows_in, a.rows_out, a.groups) \\
            == (c.rows_in, c.rows_out, c.groups), key
    print("OBS-IDENTICAL")

    # -- adaptive drift swaps on the mesh path: every batch bit-identical
    #    to eager while the calibrated plan is swapped in ------------------
    n = 4096
    S = Schema.of(k=np.int64, v=np.int64, w=np.int64)
    srcn = F.source("I", S, num_records=n)
    def keep(ir, out):
        out.emit(ir.copy(), where=ir.get("w") > 0)
    filt = F.map_(srcn, keep, name="Keep", hints=Hints(selectivity=0.9))
    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))
    root = F.reduce_(filt, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=64))
    def mk(seed, drift=0.0):
        rng = np.random.default_rng(seed)
        lo = -1 if drift == 0.0 else -19   # drift crushes selectivity
        return {"I": batch_from_dict({
            "k": rng.integers(0, 64, n),
            "v": rng.integers(-100, 100, n),
            "w": rng.integers(lo, 2, n)})}

    cache = ExecutableCache()
    cur_root = root
    res = optimize(cur_root, Ctx(dop=8), include_commutes=False)
    dp = DX.DistributedPlan(res, mesh_shards=8, cache=cache)
    store = StatsStore()
    swaps = 0
    for t in range(8):
        b = mk(100 + t, drift=0.0 if t < 3 else 0.9)
        store.tick()
        out = dp.run(b, stats_store=store)
        assert canonical_rows(out) == canonical_rows(
            executor.execute(root, b)), t
        if drift_score(cur_root, store) > 0.5:
            cal = calibrate_hints(root, store, prior_weight=0.0)
            if semantic_key(cal) != semantic_key(cur_root):
                cur_root = cal
                res = optimize(cur_root, Ctx(dop=8),
                               include_commutes=False)
                dp = DX.DistributedPlan(res, mesh_shards=8, cache=cache)
                store = StatsStore()
                swaps += 1
    assert swaps >= 1, swaps
    print("ADAPTIVE-SWAPS=%d" % swaps)

    # -- warm mesh serving: second run hits the executable cache -----------
    b = mk(999)
    dp.run(b)
    st0 = dp.cache_stats()
    dp.run(b)
    st1 = dp.cache_stats()
    assert st1.traces == st0.traces and st1.hits == st0.hits + 1
    print("WARM-CACHE-OK")
""")


def test_mesh_overlap_corpus_and_adaptive():
    """8-way mesh acceptance (subprocess so the forced device count cannot
    leak): corpus bit-identity between sliced and serial wire, psum'd
    observation equality, adaptive drift swaps with bit-identical serving,
    warm-cache behaviour."""
    here = os.path.dirname(__file__)
    src = os.path.join(here, "..", "src")
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT, src, here],
                       capture_output=True, text=True, timeout=570)
    assert r.returncode == 0, (r.stdout[-2000:], r.stderr[-4000:])
    for marker in ("CORPUS-IDENTICAL", "OBS-IDENTICAL", "ADAPTIVE-SWAPS",
                   "WARM-CACHE-OK"):
        assert marker in r.stdout, r.stdout
