"""Unit tests for the reordering conditions (paper Sec. 4)."""

import numpy as np

from repro.core import flow as F
from repro.core import executor
from repro.core.enumeration import enumerate_plans
from repro.core.operators import Hints, MatchOp, ReduceOp
from repro.core.record import Schema, batch_from_dict
from repro.core.reorder import (commute, pull_unary_from_binary,
                                push_unary_into_binary, reorderable, roc,
                                rotate, swap_unary)

S_AB = Schema.of(A=np.int64, B=np.int64)


def _maps():
    def f1(ir, out):
        out.emit(ir.copy().set("B", abs(ir.get("B"))))

    def f2(ir, out):
        out.emit(ir.copy(), where=ir.get("A") >= 0)

    def f3(ir, out):
        out.emit(ir.copy().set("A", ir.get("A") + ir.get("B")))

    src = F.source("I", S_AB)
    m1 = F.map_(src, f1, name="M1")
    m2 = F.map_(m1, f2, name="M2")
    m3 = F.map_(m2, f3, name="M3")
    return src, m1, m2, m3


def test_theorem1_roc_decides_map_swap():
    src, m1, m2, m3 = _maps()
    assert roc(m2, m1) and reorderable(m2, m1)      # no conflict
    assert not roc(m3, m1)                          # W1 ∩ R3 = {B}
    assert swap_unary(m2, m1) is not None
    # rebuilt tree keeps semantics
    t = swap_unary(m2, m1)
    assert t.op_names()[0] == "M1"  # M1 now root of the subtree


def test_theorem2_kgp_required():
    src = F.source("I", S_AB)

    def filt_key(ir, out):
        out.emit(ir.copy(), where=ir.get("A") > 0)

    def filt_nonkey(ir, out):
        out.emit(ir.copy(), where=ir.get("B") > 0)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("B")))

    r_key = F.reduce_(F.map_(src, filt_key, name="FK"), ["A"], agg, name="R")
    r_non = F.reduce_(F.map_(src, filt_nonkey, name="FN"), ["A"], agg, name="R")
    assert swap_unary(r_key, r_key.child) is not None   # filter on key: OK
    assert swap_unary(r_non, r_non.child) is None       # KGP fails


def test_invariant_grouping_needs_pk():
    li = F.source("L", Schema.of(k=np.int64, v=np.float64))
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64), num_records=10)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    for pk, expect in (("right", True), (None, False)):
        j = F.match(F.reduce_(li, ["k"], agg, name="R"), su, ["k"], ["sk"],
                    name="J", hints=Hints(pk_side=pk))
        got = pull_unary_from_binary(j, 0)
        assert (got is not None) == expect, pk
        if got is not None:
            assert isinstance(got, ReduceOp)
            assert got.attrs() == j.attrs()  # schema preserved (extension)


def test_push_map_requires_single_side_refs():
    l = F.source("L", Schema.of(a=np.int64, k=np.int64))
    r = F.source("R", Schema.of(b=np.int64, j=np.int64))
    j = F.match(l, r, ["k"], ["j"], name="J")

    def left_only(ir, out):
        out.emit(ir.copy(), where=ir.get("a") > 0)

    def both_sides(ir, out):
        out.emit(ir.copy(), where=ir.get("a") > ir.get("b"))

    ml = F.map_(j, left_only, name="ML")
    mb = F.map_(j, both_sides, name="MB")
    assert push_unary_into_binary(ml, j, 0) is not None
    assert push_unary_into_binary(ml, j, 1) is None
    assert push_unary_into_binary(mb, j, 0) is None
    assert push_unary_into_binary(mb, j, 1) is None


def test_rotation_lemma1():
    a = F.source("A", Schema.of(k1=np.int64, x=np.int64))
    b = F.source("B", Schema.of(k1b=np.int64, k2=np.int64))
    c = F.source("C", Schema.of(k2c=np.int64, z=np.int64))
    j1 = F.match(a, b, ["k1"], ["k1b"], name="J1")
    j2 = F.match(j1, c, ["k2"], ["k2c"], name="J2")  # key k2 lives in B
    t = rotate(j2, 0)
    assert t is not None and isinstance(t, MatchOp)
    assert t.name == "J1"  # J1 hoisted to root: A ⋈1 (B ⋈2 C)
    # rotation whose parent key refers to the OTHER side is rejected
    j2x = F.match(j1, c, ["x"], ["k2c"], name="J2x")  # x lives in A
    assert rotate(j2x, 0) is None


def test_commute_swaps_sides_and_udf_args():
    l = F.source("L", Schema.of(a=np.int64, k=np.int64))
    r = F.source("R", Schema.of(b=np.int64, j=np.int64))
    j = F.match(l, r, ["k"], ["j"], name="J", hints=Hints(pk_side="right"))
    cj = commute(j)
    assert cj.left.name == "R" and cj.right.name == "L"
    assert cj.left_key == ("j",) and cj.hints.pk_side == "left"
    ld = batch_from_dict({"a": np.arange(5), "k": np.arange(5) % 3})
    rd = batch_from_dict({"b": np.arange(3) * 10, "j": np.arange(3)})
    out1 = executor.execute(j, {"L": ld, "R": rd})
    out2 = executor.execute(cj, {"L": ld, "R": rd})
    assert out1.equivalent(out2)


def test_schema_dependent_blocks_swaps():
    from repro.core.udf import UdfProperties
    from repro.core.udf import Card

    src = F.source("I", S_AB)

    def adder(ir, out):  # adds attribute C
        out.emit(ir.copy().set("C", ir.get("A") * 2))

    def dynamic(ir, out):
        _ = ir.fields
        out.emit(ir.copy(), where=ir.get("B") > 0)

    m1 = F.map_(src, adder, name="ADD")
    m2 = F.map_(m1, dynamic, name="DYN")
    assert m2.props.schema_dependent
    assert swap_unary(m2, m1) is None  # ADD changes schema under DYN

    def pure(ir, out):
        out.emit(ir.copy(), where=ir.get("B") > 0)

    m2p = F.map_(m1, pure, name="PURE")
    assert swap_unary(m2p, m1) is not None


def test_enumeration_counts_on_paper_flows():
    from repro.configs import flows

    # (pure reorderings — the paper's Table-1 spaces, aggregation-split
    # variants): splitting enlarges every flow with a decomposable Reduce
    # (q7's AggRevenue, q15's AggRevenue, clickstream's CondenseSessions)
    # and leaves the all-Map textmining flow untouched.
    expected = {"q7": (41, 100), "q15": (3, 7), "clickstream": (9, 23),
                "textmining": (24, 24)}
    for name, (want, want_split) in expected.items():
        root, _ = flows.FLOWS[name]()
        plans = enumerate_plans(root, include_commutes=False,
                                split_reduces=False)
        assert len(plans) == want, (name, len(plans))
        split_plans = enumerate_plans(root, include_commutes=False)
        assert len(split_plans) == want_split, (name, len(split_plans))
