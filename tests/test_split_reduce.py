"""Decomposable aggregation push-down: split-Reduce rewrite, combiner
physical strategy, eager-aggregation push below PK joins, and the
distributed acceptance bar (combiner inserted + >=3x fewer rows crossing
the repartition collective on a >=64-group / >=8k-row flow)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import executor, flow as F
from repro.core.cost import estimate
from repro.core.enumeration import enumerate_plans
from repro.core.masked import run_flow_jit
from repro.core.operators import Hints, ReduceOp
from repro.core.optimizer import optimize, optimize_two_phase
from repro.core.physical import Ctx
from repro.core.record import Schema, batch_from_dict
from repro.core.reorder import (pull_combiner_from_binary,
                                push_combiner_into_binary, split_reduce,
                                unsplit_reduce)

SCHEMA = Schema.of(k=np.int64, v=np.int64, w=np.float64)
N_ROWS, N_GROUPS = 8192, 64


def _agg_flow(num_records=N_ROWS):
    src = F.source("I", SCHEMA, num_records=num_records)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")).set("mx", g.max("v"))
                 .set("avg", g.mean("w")).set("n", g.count()))

    return F.reduce_(src, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))


def _bindings(seed=0, n=N_ROWS):
    rng = np.random.default_rng(seed)
    return {"I": batch_from_dict({"k": rng.integers(0, N_GROUPS, n),
                                  "v": rng.integers(-100, 100, n),
                                  "w": rng.uniform(0, 1, n)})}


# ---------------------------------------------------------------------------
# The rewrite
# ---------------------------------------------------------------------------
def test_split_preserves_schema_and_roundtrips():
    root = _agg_flow()
    split = split_reduce(root)
    assert split is not None
    pre, merge = split.child, split
    assert isinstance(pre, ReduceOp) and pre.combiner
    assert isinstance(merge, ReduceOp) and not merge.combiner
    assert tuple(merge.out_schema.fields) == tuple(root.out_schema.fields)
    assert all(merge.out_schema.dtypes[f] == root.out_schema.dtypes[f]
               for f in root.out_schema.fields)
    back = unsplit_reduce(split)
    assert back is not None and back.canonical() == root.canonical()
    # splitting is idempotent: neither half splits again
    assert split_reduce(pre) is None
    assert split_reduce(merge) is None


def test_split_plans_equivalent_eager_and_jit():
    root = _agg_flow()
    split = split_reduce(root)
    b = _bindings(3)
    ref = executor.execute(root, b)
    assert executor.execute(split, b).equivalent(ref, atol=1e-6)
    assert run_flow_jit(split, b).equivalent(ref, atol=1e-4)
    # integer aggregates are BIT-identical across the split
    ref_ints = {f: sorted(np.asarray(ref[f]).tolist())
                for f in ("k", "s", "mx", "n")}
    got = executor.execute(split, b)
    for f, vals in ref_ints.items():
        assert sorted(np.asarray(got[f]).tolist()) == vals


def test_schema_dependent_reduce_never_decomposable():
    """A schema-reflecting Reduce UDF must not receive a combine recipe
    (the merge replay presents the ORIGINAL field list, which a rewritten
    plan may have changed) — regression: the jaxpr path once attached the
    recipe BEFORE OR-ing in the bytecode schema_dependent flag."""
    src = F.source("I", SCHEMA, num_records=1000)

    def agg(g, out):
        n_fields = len(g.fields)  # schema reflection
        out.emit(g.keys().set("s", g.sum("v") * n_fields))

    r = F.reduce_(src, ["k"], agg, name="Agg")
    assert r.props.schema_dependent
    assert r.props.combine is None
    assert split_reduce(r) is None


def test_non_decomposable_reduce_does_not_split():
    src = F.source("I", SCHEMA, num_records=1000)

    def keep(g, out):
        out.emit_records(where=g.any(g.get("v") > 0))

    r = F.reduce_(src, ["k"], keep, name="Keep")
    assert r.props.combine is None
    assert split_reduce(r) is None


# ---------------------------------------------------------------------------
# Physical strategies + costing
# ---------------------------------------------------------------------------
def test_optimizer_inserts_combiner_on_shuffle_flow():
    """Acceptance: on a Reduce-after-shuffle flow with >=64 groups over
    >=8k rows the chosen plan contains the combiner below the merge."""
    root = _agg_flow()
    res = optimize(root, Ctx(dop=8))
    names = [p.node.name for p in _walk(res.best.plan)]
    assert "Agg.pre" in names and "Agg.merge" in names
    pre_plan = next(p for p in _walk(res.best.plan)
                    if p.node.name == "Agg.pre")
    merge_plan = next(p for p in _walk(res.best.plan)
                      if p.node.name == "Agg.merge")
    assert pre_plan.ship == ("forward",)       # combiner never ships
    assert pre_plan.node_cost.net == 0.0
    assert merge_plan.ship == ("partition",)   # merge pays the (small) shuffle
    # the interleaved search and the exhaustive reference agree
    two = optimize_two_phase(root, Ctx(dop=8))
    assert res.best.flow.op_names() == two.best.flow.op_names()
    assert abs(res.best.cost - two.best.cost) <= 1e-12


def test_partitioned_source_keeps_unsplit_plan():
    """When the source is already partitioned on the key there is nothing to
    save: the unsplit forward Reduce must win (the combiner adds work)."""
    src = F.source("I", SCHEMA, num_records=N_ROWS, partitioned_on=["k"])

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    root = F.reduce_(src, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))
    res = optimize(root, Ctx(dop=8))
    assert ".pre" not in res.best.order()
    plan = res.best.plan
    assert plan.ship == ("forward",)


def test_combiner_estimate_scales_with_dop():
    root = _agg_flow()
    split = split_reduce(root)
    pre = split.child
    assert estimate(pre, {}, dop=1).rows == N_GROUPS
    assert estimate(pre, {}, dop=8).rows == N_GROUPS * 8
    # capped by the input cardinality
    assert estimate(pre, {}, dop=10 ** 6).rows == N_ROWS
    # the merge consumes the combiner's (dop-scaled) output
    assert estimate(split, {}, dop=8).rows == N_GROUPS


def _walk(plan):
    yield plan
    for i in plan.inputs:
        yield from _walk(i)


# ---------------------------------------------------------------------------
# Eager aggregation: combiner below a PK-FK Match
# ---------------------------------------------------------------------------
def _join_flow():
    src = F.source("I", SCHEMA, num_records=N_ROWS)
    dim = F.source("Dim", Schema.of(dk=np.int64, dv=np.int64),
                   num_records=N_GROUPS)
    j = F.match(src, dim, ["k"], ["dk"], name="J",
                hints=Hints(pk_side="right"))

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    return F.reduce_(j, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))


def test_push_combiner_below_pk_match_and_back():
    root = _join_flow()
    split = split_reduce(root)
    pushed = push_combiner_into_binary(split, 0)
    assert pushed is not None
    # tree shape: merge over Match over (pre over I, Dim)
    assert pushed.name == "Agg.merge"
    assert pushed.child.name == "J"
    assert pushed.child.children[0].name == "Agg.pre"
    assert tuple(pushed.out_schema.fields) == tuple(root.out_schema.fields)
    back = pull_combiner_from_binary(pushed, 0)
    assert back is not None and back.canonical() == split.canonical()
    # no push into the PK side (the combiner's key lives on the FK side)
    assert push_combiner_into_binary(split, 1) is None

    b = _bindings(5)
    b["Dim"] = batch_from_dict({"dk": np.arange(N_GROUPS),
                                "dv": np.arange(N_GROUPS) * 3})
    ref = executor.execute(root, b)
    for t in (split, pushed):
        assert executor.execute(t, b).equivalent(ref, atol=1e-6)


def test_no_push_without_pk_guard():
    """A general (non-PK) join blocks the eager push — invariant grouping
    needs the other side to hold at most one partner per group."""
    src = F.source("I", SCHEMA, num_records=N_ROWS)
    dim = F.source("Dim", Schema.of(dk=np.int64, dv=np.int64),
                   num_records=N_GROUPS)
    j = F.match(src, dim, ["k"], ["dk"], name="J")  # no pk_side hint

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    root = F.reduce_(j, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=N_GROUPS))
    split = split_reduce(root)
    assert split is not None
    assert push_combiner_into_binary(split, 0) is None
    assert push_combiner_into_binary(split, 1) is None


def test_closure_contains_split_and_pushed_plans():
    root = _join_flow()
    cans = {p.canonical() for p in enumerate_plans(root, max_plans=5000)}
    assert any(".pre" in c and ".merge" in c for c in cans)
    # eager-aggregation variant: pre inside the join's left input
    assert any("J(Agg.pre" in c for c in cans)
    # reordering-only space excludes all of them
    cans0 = {p.canonical()
             for p in enumerate_plans(root, split_reduces=False)}
    assert not any(".pre" in c for c in cans0)
    assert cans0 < cans


# ---------------------------------------------------------------------------
# Distributed acceptance: combiner before the repartition collective
# ---------------------------------------------------------------------------
_DISTRIBUTED_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, %r)
    import numpy as np
    from repro.core import executor, flow as F
    from repro.core import distributed as DX
    from repro.core.operators import Hints
    from repro.core.optimizer import optimize
    from repro.core.physical import Ctx
    from repro.core.record import Schema, batch_from_dict

    S = Schema.of(k=np.int64, v=np.int64, w=np.float64)
    src = F.source("I", S, num_records=8192)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")).set("avg", g.mean("w")))

    root = F.reduce_(src, ["k"], agg, name="Agg",
                     hints=Hints(distinct_keys=64))
    rng = np.random.default_rng(11)
    b = {"I": batch_from_dict({"k": rng.integers(0, 64, 8192),
                               "v": rng.integers(-100, 100, 8192),
                               "w": rng.uniform(0, 1, 8192)})}
    ref = executor.execute(root, b)

    res = optimize(root, Ctx(dop=8))
    assert ".pre" in res.best.order(), res.best.order()
    stats = DX.shuffle_stats()
    stats.clear()
    split_out = DX.execute_distributed(res.best.plan, b)
    assert split_out.equivalent(ref, atol=1e-4)
    split_wire = stats.wire_rows
    assert stats.collectives == 1

    unsplit = next(rp for rp in res.ranked if ".pre" not in rp.order())
    stats.clear()
    un_out = DX.execute_distributed(unsplit.plan, b)
    assert un_out.equivalent(ref, atol=1e-4)
    un_wire = stats.wire_rows

    # integer aggregate columns are bit-identical between split and unsplit
    for f in ("k", "s"):
        assert sorted(np.asarray(split_out[f]).tolist()) \\
            == sorted(np.asarray(un_out[f]).tolist()), f
    ratio = un_wire / split_wire
    assert ratio >= 3.0, (un_wire, split_wire)
    print("OK ratio=%%.1f split=%%d unsplit=%%d"
          %% (ratio, split_wire, un_wire))
""")


@pytest.mark.parametrize("dummy", [0])
def test_distributed_combiner_reduces_shuffle_rows(dummy):
    """Acceptance: on 8 workers the chosen split plan ships >=3x fewer rows
    through the repartition all_to_all than the unsplit plan, with
    bit-identical integer aggregates.  Runs in a subprocess so the forced
    8-device host platform cannot leak into other tests."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT % src],
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout
