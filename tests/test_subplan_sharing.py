"""Cross-tenant common-subplan sharing (DESIGN.md §13, serving side).

Two tenants in DIFFERENT plan groups whose flows open with the same
source → map-chain prefix — detected through the commute-invariant
`semantic_key` of the prefix subtree — execute one fused upstream stage per
batch, feeding each tenant's own suffix plan.  These tests cover the
detection, result parity, the statistics contract (fused-prefix
observations are attributed ONCE to the share group's store, never
per-consuming tenant), drift isolation (one sharer drifting re-links under
its new regime and leaves the group; the other stays warm), and the
`REPRO_SUBPLAN_SHARING` kill switch.
"""

import numpy as np

from repro.core import executor, flow as F
from repro.core.operators import Hints
from repro.core.record import RecordBatch, Schema
from repro.serve.dataflow import (DataflowEngine, ServeConfig,
                                  coalesce_flow, shared_prefix)

SCH = Schema.of(a=np.int64, b=np.int64, c=np.int64)


def _keep(r, out):
    out.emit(r.copy(), where=r.get("c") < 80)


def _inc(r, out):
    out.emit(r.copy().set("c", r.get("c") + 1))


def _agg_b(g, out):
    out.emit(g.keys().set("s", g.sum("b")))


def _agg_c(g, out):
    out.emit(g.keys().set("s", g.sum("c")))


def _flow(which: int, n: int = 128):
    """Shared prefix (keep → inc over source `s`), per-tenant suffix."""
    src = F.source("s", SCH, num_records=n)
    pre = F.map_(F.map_(src, _keep, name="keep",
                        hints=Hints(selectivity=0.8)), _inc, name="inc")
    if which == 0:
        return F.reduce_(pre, ["a"], _agg_b, name="aggb",
                         hints=Hints(distinct_keys=10))
    return F.reduce_(pre, ["b"], _agg_c, name="aggc",
                     hints=Hints(distinct_keys=6))


def _data(seed: int, n: int = 128, c_hi: int = 100) -> RecordBatch:
    rng = np.random.default_rng(seed)
    return RecordBatch(
        {"a": rng.integers(0, 10, n).astype(np.int64),
         "b": rng.integers(0, 6, n).astype(np.int64),
         "c": rng.integers(0, c_hi, n).astype(np.int64)})


def _rows(batch):
    b = batch.to_numpy().compact()
    fields = sorted(b.fields)
    return sorted(zip(*[np.asarray(b.columns[f]).tolist() for f in fields]))


# -- prefix detection --------------------------------------------------------
def test_shared_prefix_detection():
    sp = shared_prefix(_flow(0))
    assert sp is not None and sp.source == "s"
    assert set(sp.prefix.op_names()) == {"s", "keep", "inc"}
    # the suffix replaces the prefix with a stub Source of its out-schema,
    # under the ORIGINAL source's name (so serve-time rebinding is a dict put)
    assert set(sp.suffix.op_names()) == {"aggb", "s"}
    assert sp.suffix.children[0].out_schema == sp.prefix.out_schema
    # a bare map chain leaves no per-tenant suffix: nothing to share
    bare = F.map_(F.source("s", SCH), _keep)
    assert shared_prefix(bare) is None
    # a flow opening with a non-Map stage has no shareable prefix
    red = F.reduce_(F.source("s", SCH), ["a"], _agg_b,
                    hints=Hints(distinct_keys=10))

    def inc_s(r, out):
        out.emit(r.copy().set("s", r.get("s") + 1))

    assert shared_prefix(F.map_(red, inc_s)) is None


def test_shared_prefix_key_is_commute_invariant_and_regime_sensitive():
    from repro.core.pipeline import semantic_key

    k0 = semantic_key(shared_prefix(_flow(0)).prefix)
    k1 = semantic_key(shared_prefix(_flow(1)).prefix)
    assert k0 == k1    # same prefix, different suffixes
    # different hint regime on a prefix stage -> different share key
    src = F.source("s", SCH, num_records=128)
    other = F.reduce_(
        F.map_(F.map_(src, _keep, name="keep",
                      hints=Hints(selectivity=0.1)), _inc, name="inc"),
        ["a"], _agg_b, name="aggb", hints=Hints(distinct_keys=10))
    assert semantic_key(shared_prefix(other).prefix) != k0


# -- serving: sharing fires, results stay correct ----------------------------
def _engine(**kw) -> DataflowEngine:
    kw = {"async_swap": False, "probe_every": 1000, "share_subplans": True,
          **kw}
    eng = DataflowEngine(ServeConfig(**kw))
    eng.register("ta", _flow(0), seed_stats=False)
    eng.register("tb", _flow(1), seed_stats=False)
    return eng


def test_shared_serving_parity_and_counters():
    eng = _engine()
    assert eng.tenant_stats("ta")["share_group_size"] == 2
    data = _data(7)
    reqs = []
    for _ in range(4):
        reqs.append((eng.submit("ta", {"s": data}),
                     eng.submit("tb", {"s": data})))
        eng.drain()
    st = eng.stats()
    # round 1 probes both tenants solo; rounds 2-4 share the fused prefix
    assert st["shared_prefix_batches"] == 3, st
    assert st["shared_requests"] == 6, st
    assert st["share_groups"] == 1
    ref_a = _rows(executor.execute(_flow(0), {"s": data}))
    ref_b = _rows(executor.execute(_flow(1), {"s": data}))
    for ra, rb in reqs:
        assert _rows(ra.result(10)) == ref_a
        assert _rows(rb.result(10)) == ref_b


def test_sharing_requires_identical_source_batch():
    eng = _engine()
    da, db = _data(1), _data(2)
    for _ in range(3):
        ra = eng.submit("ta", {"s": da})
        rb = eng.submit("tb", {"s": db})   # different batch: no pairing
        eng.drain()
        ra.result(10), rb.result(10)
    assert eng.stats()["shared_prefix_batches"] == 0


def test_sharing_requires_distinct_plan_groups():
    # two tenants with THE SAME flow live in one plan group — coalescing
    # already covers them; the shared-prefix path must not hijack the queue
    cfg = ServeConfig(async_swap=False, probe_every=1000, share_subplans=True)
    eng = DataflowEngine(cfg)
    eng.register("ta", _flow(0), seed_stats=False)
    eng.register("tb", _flow(0), seed_stats=False)
    data = _data(3)
    for _ in range(3):
        ra, rb = eng.submit("ta", {"s": data}), eng.submit("tb", {"s": data})
        eng.drain()
        ra.result(10), rb.result(10)
    st = eng.stats()
    assert st["shared_prefix_batches"] == 0
    assert st["coalesced_requests"] >= 4


# -- the statistics contract -------------------------------------------------
def test_shared_stage_observed_once_and_tenant_stores_disjoint():
    eng = _engine()
    data = _data(11)
    for _ in range(5):
        eng.submit("ta", {"s": data})
        eng.submit("tb", {"s": data})
        eng.drain()
    ta, tb = eng._tenants["ta"], eng._tenants["tb"]
    sg = eng._prefixes[ta.prefix_key]
    # fused-prefix obs land in the share store: one tick per fused batch,
    # NOT one per consuming tenant
    assert sg.store.clock == eng.stats()["shared_prefix_batches"] == 4
    # each tenant's store: 1 solo probe + its 4 shared suffix runs
    assert ta.store.clock == tb.store.clock == 5
    # the prefix ops were observed into a tenant store only by its OWN solo
    # probe — shared batches never touched them
    for t in (ta, tb):
        pre_keys = [k for k in t.store._stages
                    if set(k) & {"keep", "inc"}]
        assert pre_keys, "solo probe should observe the prefix stage"
        assert all(t.store._stages[k].batches == 1 for k in pre_keys), \
            {k: t.store._stages[k].batches for k in pre_keys}
    # suffix stages accumulated per tenant, disjoint op names
    def has(store, op):
        return any(any(op in name for name in k) for k in store._stages)

    assert has(ta.store, "aggb") and not has(ta.store, "aggc")
    assert has(tb.store, "aggc") and not has(tb.store, "aggb")


# -- drift isolation ---------------------------------------------------------
def test_drifting_sharer_leaves_group_and_peer_stays_warm():
    eng = _engine(probe_every=2, drift_high=0.4, drift_low=0.2, patience=1,
                  min_drift_rows=0.0)
    warm = _data(21)              # matches the registered hint regime
    drifted = _data(22, c_hi=400)  # filter passes ~0.2 vs the hinted 0.8
    key0 = eng._tenants["ta"].prefix_key
    for i in range(14):
        eng.submit("ta", {"s": drifted})
        eng.submit("tb", {"s": warm})
        eng.drain()
    ta, tb = eng._tenants["ta"], eng._tenants["tb"]
    assert ta.swaps >= 1, eng.tenant_stats("ta")
    assert tb.swaps == 0, eng.tenant_stats("tb")
    # the drifter re-linked under its new regime's prefix key...
    assert ta.prefix_key != key0
    # ...and left the old share group; the peer keeps it (now solo-sized)
    assert tb.prefix_key == key0
    assert eng._prefixes[key0].members == {"tb"}
    # correctness throughout: spot-check the final round
    ra = eng.submit("ta", {"s": drifted})
    rb = eng.submit("tb", {"s": warm})
    eng.drain()
    assert _rows(ra.result(10)) == _rows(
        executor.execute(_flow(0), {"s": drifted}))
    assert _rows(rb.result(10)) == _rows(
        executor.execute(_flow(1), {"s": warm}))


# -- kill switch and coalescing gates ----------------------------------------
def test_share_subplans_kill_switch():
    cfg = ServeConfig(async_swap=False, probe_every=1000,
                      share_subplans=False)
    eng = DataflowEngine(cfg)
    eng.register("ta", _flow(0), seed_stats=False)
    eng.register("tb", _flow(1), seed_stats=False)
    data = _data(5)
    for _ in range(3):
        eng.submit("ta", {"s": data})
        eng.submit("tb", {"s": data})
        eng.drain()
    st = eng.stats()
    assert st["share_groups"] == 0 and st["shared_requests"] == 0


def test_subplan_sharing_env_default(monkeypatch):
    monkeypatch.setenv("REPRO_SUBPLAN_SHARING", "0")
    assert ServeConfig().share_subplans is False
    monkeypatch.setenv("REPRO_SUBPLAN_SHARING", "1")
    assert ServeConfig().share_subplans is True


def test_coalesce_flow_new_operator_gates():
    # anti joins coalesce with the anti flag intact (tag keys on both sides
    # keep the existence test per-request)
    f_anti = F.match(F.source("s", SCH, num_records=64),
                     F.source("r", Schema.of(k=np.int64), num_records=8),
                     ["a"], ["k"], anti=True, name="anti")
    cf = coalesce_flow(f_anti, 4)
    assert cf is not None
    assert any(getattr(n, "anti", False) for n in cf.root.iter_nodes())
    # a global top-k cannot be keyed per request: not coalescable
    f_lim = F.limit_(F.map_(F.source("s", SCH, num_records=64), _inc),
                     k=5, key=("a",))
    assert coalesce_flow(f_lim, 4) is None
