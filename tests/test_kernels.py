"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

# optional dependency: skip cleanly (instead of failing collection)
# in environments without hypothesis
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("n,c,op", [
    (64, 3, "add"), (512, 1, "max"), (1000, 2, "min"), (48, 4, "add"),
    (8, 1, "max"), (4096, 2, "add"),
])
def test_segmented_scan(n, c, op):
    v = jnp.asarray(RNG.normal(size=(n, c)).astype(np.float32))
    flags = jnp.asarray(RNG.random(n) < 0.2).at[0].set(True)
    np.testing.assert_allclose(ops.segmented_scan(v, flags, op=op),
                               ref.segmented_scan(v, flags, op=op),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n,nseg,op,frac_valid", [
    (128, 16, "add", 0.8), (1000, 50, "max", 0.5), (256, 8, "min", 1.0),
    (64, 64, "add", 0.3),
])
def test_segment_reduce(n, nseg, op, frac_valid):
    sid = np.sort(RNG.integers(0, nseg, n)).astype(np.int32)
    v = RNG.normal(size=n).astype(np.float32)
    valid = RNG.random(n) < frac_valid
    got = ops.segment_reduce(jnp.asarray(v), jnp.asarray(sid), nseg, op=op,
                             valid=jnp.asarray(valid))
    want = ref.segment_reduce(jnp.asarray(v), jnp.asarray(sid), nseg, op=op,
                              valid=jnp.asarray(valid))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 300), m=st.integers(1, 300),
       lo=st.integers(-100, 0), hi=st.integers(1, 1000))
def test_sorted_probe_property(n, m, lo, hi):
    keys = np.sort(RNG.integers(lo, hi, n)).astype(np.float64)
    qs = RNG.integers(lo - 5, hi + 5, m).astype(np.float64)
    got = ops.sorted_probe(jnp.asarray(keys), jnp.asarray(qs))
    want = ref.sorted_probe(jnp.asarray(keys), jnp.asarray(qs))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape,causal,window,dt,tol", [
    ((1, 4, 2, 128, 128, 64), True, None, jnp.float32, 2e-5),
    ((2, 8, 8, 64, 64, 32), True, None, jnp.bfloat16, 2e-2),
    ((1, 4, 1, 128, 256, 64), True, None, jnp.float32, 2e-5),   # GQA prefill
    ((1, 2, 2, 96, 96, 64), True, 32, jnp.float32, 2e-5),        # window
    ((1, 2, 2, 64, 64, 128), False, None, jnp.float32, 2e-5),
    ((1, 4, 2, 1, 128, 64), True, None, jnp.float32, 2e-5),      # decode q
    ((1, 1, 1, 256, 256, 64), True, 128, jnp.bfloat16, 2e-2),
])
def test_flash_attention(shape, causal, window, dt, tol):
    b, hq, hkv, t, s, d = shape
    q = jnp.asarray(RNG.normal(size=(b, hq, t, d)), dt)
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dt)
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)), dt)
    got = ops.flash_attention(q, k, v, causal=causal, window=window)
    want = ref.attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,h,t,dk,dv", [
    (1, 2, 64, 16, 16), (2, 1, 128, 32, 64), (1, 1, 256, 64, 64),
])
def test_rwkv6_kernel(b, h, t, dk, dv):
    r = jnp.asarray(RNG.normal(size=(b, h, t, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, t, dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, t, dv)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.3, 0.99, size=(b, h, t, dk)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, dk)), jnp.float32)
    np.testing.assert_allclose(ops.rwkv6(r, k, v, w, u),
                               ref.rwkv6(r, k, v, w, u),
                               rtol=3e-4, atol=3e-4)


def test_rwkv6_chunked_matches_scan():
    b, h, t, dk, dv = 2, 3, 128, 32, 48
    r = jnp.asarray(RNG.normal(size=(b, h, t, dk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, t, dk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, t, dv)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.5, 0.995, size=(b, h, t, dk)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, dk)), jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(b, h, dk, dv)) * 0.1, jnp.float32)
    want, sw = ref.rwkv6(r, k, v, w, u, state=s0, return_state=True)
    got, sg = ref.rwkv6_chunked(r, k, v, w, u, chunk=32, state=s0,
                                return_state=True)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(sg, sw, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("g,t,d", [(2, 64, 8), (1, 500, 16), (3, 256, 128)])
def test_linear_scan_kernel(g, t, d):
    a = jnp.asarray(RNG.uniform(0.2, 0.99, size=(g, t, d)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(g, t, d)), jnp.float32)
    np.testing.assert_allclose(ops.linear_scan(a, b), ref.linear_scan(a, b),
                               rtol=1e-4, atol=1e-4)


def test_linear_scan_chunked_and_grad():
    import jax

    g, t, d = 2, 512, 16
    a = jnp.asarray(RNG.uniform(0.2, 0.99, size=(g, t, d)), jnp.float32)
    b = jnp.asarray(RNG.normal(size=(g, t, d)), jnp.float32)
    np.testing.assert_allclose(ref.linear_scan_chunked(a, b, chunk=128),
                               ref.linear_scan(a, b), rtol=1e-4, atol=1e-4)
    # chunk-checkpointed version must be differentiable
    f = lambda a_, b_: ref.linear_scan_chunked(a_, b_, chunk=128).sum()
    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    assert np.isfinite(np.asarray(ga)).all() and np.isfinite(np.asarray(gb)).all()
