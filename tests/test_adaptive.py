"""Adaptive statistics feedback (DESIGN.md §9): StatsStore accumulation and
cross-shard merge, calibrate_hints posterior math, drift-score hysteresis
(no thrash on noisy-but-stationary serving), calibration-regime executable
cache semantics, and the truncation-repair guarantee."""

import math

import numpy as np
import pytest

from repro.configs import flows
from repro.core import cost, executor
from repro.core import flow as F
from repro.core.cost import StatsStore, calibrate_hints, drift_score
from repro.core.operators import Hints
from repro.core.pipeline import (AdaptiveConfig, ExecutableCache,
                                 compile_plan, semantic_key)
from repro.core.record import Schema, batch_from_dict


# ---------------------------------------------------------------------------
# StatsStore: accumulation, EWMA semantics, cross-shard merge
# ---------------------------------------------------------------------------
def test_store_accumulates_and_ewma():
    s = StatsStore(alpha=0.5)
    s.tick()
    s.observe_stage(("F",), (100.0,), 40.0, groups=4.0)
    o = s.stage(("F",))
    assert o.batches == 1 and o.rows_out == 40.0
    assert o.ewma_out == 40.0 and o.ewma_in == (100.0,)  # first sample snaps
    s.tick()
    s.observe_stage(("F",), (100.0,), 80.0, groups=8.0)
    o = s.stage(("F",))
    assert o.batches == 2 and o.rows_out == 120.0
    assert o.ewma_out == pytest.approx(60.0)  # 0.5 * 40 + 0.5 * 80
    assert o.ewma_groups == pytest.approx(6.0)
    assert o.last_tick == 2


def test_store_snap_overrides_history():
    s = StatsStore(alpha=0.25)
    for out in (10.0, 10.0, 10.0):
        s.tick()
        s.observe_stage(("F",), (100.0,), out)
    s.tick()
    s.observe_stage(("F",), (100.0,), 500.0, snap=True)
    # a snapped observation (truncation ground truth) replaces the EWMA
    assert s.stage(("F",)).ewma_out == 500.0


def test_store_merge_across_shards():
    a, b = StatsStore(), StatsStore()
    for _ in range(3):
        a.tick()
        a.observe_stage(("R",), (90.0,), 30.0, groups=3.0)
        a.observe_source("S", 90.0)
    b.tick()
    b.observe_stage(("R",), (30.0,), 60.0, groups=6.0)
    b.observe_source("S", 30.0)
    a.merge(b)
    o = a.stage(("R",))
    assert o.batches == 4
    assert o.rows_out == pytest.approx(150.0)
    assert o.rows_in == (pytest.approx(300.0),)
    # EWMAs combine weighted by batch counts: 3/4 * 30 + 1/4 * 60
    assert o.ewma_out == pytest.approx(37.5)
    assert o.ewma_groups == pytest.approx(3.75)
    assert a.source_rows()["S"] == pytest.approx(0.75 * 90 + 0.25 * 30)


# ---------------------------------------------------------------------------
# calibrate_hints: posterior math
# ---------------------------------------------------------------------------
def _filter_flow(sel_hint, n=1024):
    src = F.source("I", Schema.of(v=np.int64, w=np.int64), num_records=n)

    def keep(ir, out):
        out.emit(ir.copy(), where=ir.get("v") >= 0)

    return F.map_(src, keep, name="Keep", hints=Hints(selectivity=sel_hint))


def test_calibrate_full_confidence_is_quantized_observation():
    root = _filter_flow(1.0)
    s = StatsStore()
    s.tick()
    s.observe_stage(("Keep",), (1000.0,), 40.0)
    cal = calibrate_hints(root, s, prior_weight=0.0, quant=4)
    got = cal.hints.selectivity
    expect = 2.0 ** (round(math.log2(0.04) * 4) / 4)
    assert got == pytest.approx(expect)
    # the original flow is untouched (rebuild, not mutation)
    assert root.hints.selectivity == 1.0


def test_calibrate_confidence_weighting_monotone():
    """More observed batches pull the posterior monotonically from the prior
    toward the (quantized) observation."""
    root = _filter_flow(1.0)
    posts = []
    for n_batches in (1, 8, 64, 256):
        s = StatsStore()
        for _ in range(n_batches):
            s.tick()
            s.observe_stage(("Keep",), (1000.0,), 40.0)
        cal = calibrate_hints(root, s, prior_weight=4.0, quant=64)
        posts.append(cal.hints.selectivity)
    assert all(a > b for a, b in zip(posts, posts[1:]))  # prior 1.0 > obs
    assert posts[0] < 1.0
    assert posts[-1] == pytest.approx(0.04, rel=0.15)


def test_calibrate_distributes_chain_correction():
    """A fused Map chain's observed ratio splits evenly (in log space) over
    the fused ops — only the product is observable, and only the product
    prices stage boundaries."""
    src = F.source("I", Schema.of(v=np.int64), num_records=1024)

    def k1(ir, out):
        out.emit(ir.copy(), where=ir.get("v") % 2 == 0)

    def k2(ir, out):
        out.emit(ir.copy(), where=ir.get("v") % 3 == 0)

    root = F.map_(F.map_(src, k1, name="A", hints=Hints(selectivity=1.0)),
                  k2, name="B", hints=Hints(selectivity=1.0))
    s = StatsStore()
    s.tick()
    s.observe_stage(("A", "B"), (1024.0,), 64.0)  # product 1/16
    cal = calibrate_hints(root, s, prior_weight=0.0, quant=64)
    sa, sb = cal.child.hints.selectivity, cal.hints.selectivity
    assert sa == pytest.approx(0.25, rel=0.05)
    assert sb == pytest.approx(0.25, rel=0.05)
    assert sa * sb == pytest.approx(1 / 16, rel=0.05)


def test_calibrate_reduce_and_match_posteriors():
    root, _ = flows.q15()
    s = StatsStore()
    for _ in range(8):
        s.tick()
        s.observe_stage(("FilterShipdate",), (1000.0,), 40.0)
        s.observe_stage(("AggRevenue",), (40.0,), 4.0, groups=4.0)
        s.observe_stage(("JoinSupplier",), (4.0, 16.0), 4.0, groups=4.0)
    cal = calibrate_hints(root, s, prior_weight=0.0, quant=4)
    by_name = {n.name: n for n in cal.iter_nodes()}
    assert by_name["AggRevenue"].hints.distinct_keys == 4
    # the PK match observed fanout 1.0; selectivity pinned so the estimator
    # does not double-apply a factor
    assert by_name["JoinSupplier"].hints.join_fanout == pytest.approx(1.0)
    assert by_name["JoinSupplier"].hints.selectivity == 1.0
    # unobserved source is untouched
    assert by_name["FilterShipdate"].hints.selectivity == pytest.approx(
        2.0 ** (round(math.log2(0.04) * 4) / 4))


def test_calibrate_quantization_defines_stable_regimes():
    """Noisy-but-stationary observations land on the SAME posterior hints
    (same semantic key): the calibration regime is discrete."""
    root = _filter_flow(1.0)
    keys = set()
    rng = np.random.default_rng(0)
    for trial in range(6):
        s = StatsStore()
        for _ in range(8):
            s.tick()
            noisy = 40.0 * float(rng.uniform(0.95, 1.05))
            s.observe_stage(("Keep",), (1000.0,), noisy)
        cal = calibrate_hints(root, s, prior_weight=0.0, quant=4)
        keys.add(hash(semantic_key(cal)))
    assert len(keys) == 1


def test_calibrate_unobserved_flow_is_identity():
    root = _filter_flow(0.5)
    assert calibrate_hints(root, StatsStore()) is root


# ---------------------------------------------------------------------------
# drift score + hysteresis: no thrash on stationary noise, one swap on drift
# ---------------------------------------------------------------------------
def test_drift_score_zero_when_hints_true():
    root = _filter_flow(0.5)
    s = StatsStore()
    for _ in range(4):
        s.tick()
        s.observe_source("I", 1000.0)
        s.observe_stage(("Keep",), (1000.0,), 500.0)
    assert drift_score(root, s) == pytest.approx(0.0)
    s.tick()
    s.observe_stage(("Keep",), (1000.0,), 20.0, snap=True)
    assert drift_score(root, s) > 4.0


def _phase_bindings(n, pass_frac):
    """Deterministic batch where EXACTLY n*pass_frac rows pass `v < n//2`."""
    k = int(n * pass_frac)
    v = np.concatenate([np.zeros(k, np.int64),
                        np.full(n - k, n, np.int64)])
    return {"I": batch_from_dict({"v": v, "w": np.arange(n)})}


def _serving_flow(n=1024):
    src = F.source("I", Schema.of(v=np.int64, w=np.int64), num_records=n)

    def keep(ir, out):
        out.emit(ir.copy(), where=ir.get("v") < n // 2)

    return F.map_(src, keep, name="Keep", hints=Hints(selectivity=0.5))


def test_stationary_serving_never_swaps_or_retraces():
    """Honest hints + noisy-but-stationary data: zero swaps, zero warm-path
    retraces — the existing steady-state serving contract is unchanged by
    observation."""
    n = 1024
    root = _serving_flow(n)
    cache = ExecutableCache()
    cp = compile_plan(root, cache=cache,
                      adaptive=AdaptiveConfig(check_every=1, patience=1))
    rng = np.random.default_rng(1)
    for _ in range(12):
        frac = float(rng.uniform(0.45, 0.55))  # noisy around the true hint
        cp.run(_phase_bindings(n, frac))
    assert cp.swaps == 0
    s = cache.stats()
    assert s.traces == 1 and s.hits == 11


def test_hysteresis_band_holds_through_patience():
    """A single outlier batch arms the trigger but cannot swap alone when
    `patience` demands sustained drift."""
    n = 1024
    root = _serving_flow(n)
    cp = compile_plan(root, cache=ExecutableCache(),
                      adaptive=AdaptiveConfig(check_every=1, patience=3))
    for _ in range(4):
        cp.run(_phase_bindings(n, 0.5))
    cp.run(_phase_bindings(n, 0.02))   # one outlier: arms
    cp.run(_phase_bindings(n, 0.5))
    cp.run(_phase_bindings(n, 0.5))    # EWMA recovers: disarms before 3
    for _ in range(4):
        cp.run(_phase_bindings(n, 0.5))
    assert cp.swaps == 0


def test_drift_swaps_once_then_stabilizes():
    n = 1024
    root = _serving_flow(n)
    cache = ExecutableCache()
    # alpha=1: the EWMA is the last batch, so the deterministic workload
    # yields an exactly reproducible posterior per phase
    cp = compile_plan(root, cache=cache, stats=cost.StatsStore(alpha=1.0),
                      adaptive=AdaptiveConfig(check_every=1, patience=2))
    for _ in range(4):
        cp.run(_phase_bindings(n, 0.5))
    assert cp.swaps == 0
    for _ in range(10):
        cp.run(_phase_bindings(n, 1 / 32))  # sustained 16x drift
    assert cp.swaps == 1  # swapped, then steady: no thrash
    by_name = {m.name: m for m in cp.flow.iter_nodes()}
    assert by_name["Keep"].hints.selectivity == pytest.approx(1 / 32)


# ---------------------------------------------------------------------------
# Cache-regime semantics
# ---------------------------------------------------------------------------
def test_swap_is_a_cache_miss_and_regimes_coexist():
    """Pre- and post-swap executables are DISTINCT cache entries; a workload
    drifting back to its original statistics re-enters the original regime
    as a warm HIT — no retrace."""
    n = 1024
    root = _serving_flow(n)
    cache = ExecutableCache()
    cp = compile_plan(root, cache=cache, stats=cost.StatsStore(alpha=1.0),
                      adaptive=AdaptiveConfig(check_every=1, patience=2))
    for _ in range(4):
        cp.run(_phase_bindings(n, 0.5))     # regime A (the declared hints)
    for _ in range(6):
        cp.run(_phase_bindings(n, 1 / 32))  # drift -> regime B
    assert cp.swaps == 1
    s = cache.stats()
    assert s.size == 2 and s.traces == 2    # A and B coexist
    traces_after_b = cache.stats().traces
    for _ in range(6):
        cp.run(_phase_bindings(n, 0.5))     # drift BACK: posterior == 0.5
    assert cp.swaps == 2
    s = cache.stats()
    # 1/2 is on the quantization grid, so the drift-back posterior equals
    # the declared hint exactly: regime A's warm executable is re-hit
    assert s.traces == traces_after_b
    assert s.size == 2


def test_semantic_key_differs_across_calibration_regimes():
    root = _filter_flow(1.0)
    s = StatsStore()
    s.tick()
    s.observe_stage(("Keep",), (1000.0,), 40.0)
    cal = calibrate_hints(root, s, prior_weight=0.0)
    assert semantic_key(cal) != semantic_key(root)
    # re-deriving the same regime reproduces the same key (warm reuse)
    cal2 = calibrate_hints(root, s, prior_weight=0.0)
    assert semantic_key(cal2) == semantic_key(cal)


# ---------------------------------------------------------------------------
# Truncation repair: an underestimated hint must never ship missing rows
# ---------------------------------------------------------------------------
def test_underestimated_hint_repaired_not_truncated():
    """A 100x-under selectivity hint makes the shipped plan's compaction
    capacity overrun on the very first batch; the handle must detect the
    overrun from the observed counts, re-plan with the snapped observation
    and transparently re-run — returning the complete result."""
    n = 2048
    src = F.source("I", Schema.of(v=np.int64, w=np.int64), num_records=n)

    def keep(ir, out):
        out.emit(ir.copy(), where=ir.get("v") >= 0)  # keeps ~90%

    root = F.map_(src, keep, name="Keep", hints=Hints(selectivity=0.005))
    rng = np.random.default_rng(7)
    b = {"I": batch_from_dict({
        "v": rng.integers(-1, 10, n), "w": rng.integers(0, 100, n)})}
    ref = executor.execute(root, b)
    cp = compile_plan(root, cache=ExecutableCache(),
                      adaptive=AdaptiveConfig())
    out = cp.run(b)
    assert out.equivalent(ref, atol=0)
    assert cp.swaps >= 1
    # non-adaptive serving of the same flow really would have truncated
    # (the guard below is what the adaptive path is FOR)
    plain = compile_plan(root, cache=ExecutableCache())
    assert plain.run(b).capacity < ref.capacity


def test_run_device_adaptive_rejects_donation():
    root = _serving_flow(256)
    cp = compile_plan(root, cache=ExecutableCache(),
                      adaptive=AdaptiveConfig())
    staged = cp.bind_device(_phase_bindings(256, 0.5))
    with pytest.raises(ValueError, match="donate"):
        cp.run_device(staged, donate=True)
    out = cp.run_device(staged)  # non-donating adaptive device step works
    ref = executor.execute(root, _phase_bindings(256, 0.5))
    assert out.to_record_batch().equivalent(ref, atol=0)


# ---------------------------------------------------------------------------
# Distributed observation: psum-aggregated counts feed the same store
# ---------------------------------------------------------------------------
def test_distributed_observation_aggregates_global_counts():
    from repro.core.distributed import execute_distributed
    from repro.core.optimizer import optimize
    from repro.core.physical import Ctx

    root, mkb = flows.q15()
    b = mkb(1200, seed=3)
    res = optimize(root, Ctx(dop=1), include_commutes=False)
    store = StatsStore()
    out = execute_distributed(res.best.plan, b, stats_store=store)
    ref = executor.execute(root, b)
    assert out.equivalent(ref, atol=1e-4)
    src = store.source_rows()
    assert src["lineitem"] == pytest.approx(1200.0)
    keys = {k[-1] for k, _ in store.stages()}
    assert any(k.startswith("AggRevenue") for k in keys)
    # the filter stage's observed global selectivity is ~0.04
    (filt,) = [o for k, o in store.stages() if k[-1] == "FilterShipdate"]
    assert filt.ewma_out / filt.ewma_in[0] == pytest.approx(0.04, rel=0.5)
