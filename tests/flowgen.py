"""Seeded generator of random well-typed PACT flows + differential harness.

`random_flow(seed)` builds a random flow over the record API — Map
(modify/filter/add), Reduce (decomposable aggregation AND passthrough
filters), Match (PK and general equi-joins), Cross, CoGroup — over random
integer schemas, with UDFs generated as closures so the SCA analyzers derive
every property from the black box alone.  All columns are int64 (aggregate
means divide exactly-equal integer sums), so every plan in the rewrite
closure — including split Reduces — must be BIT-identical to the
unoptimized eager execution, which `assert_closure_identical` checks via
`sorted_tuples()` multiset equality (no tolerance).

The generator is deliberately constructive (ops only reference live fields)
so every seed yields a valid flow; it is driven by `numpy.random.default_rng`
and needs no optional dependencies, making the differential harness part of
tier-1.  Property-based tests can still layer hypothesis on top by drawing
the seed from a strategy.

Two adversarial modes harden the adaptive-statistics loop (DESIGN.md §9):
`adversarial_hints` perturbs every cost hint by up to 100x in either
direction (underestimates included — the direction that overruns compaction
capacities), and `bindings(seed, drift=...)` shifts the per-batch key/value
distributions mid-serve.  `assert_adaptive_identical` serves such a
workload through an adaptive `CompiledPlan` and asserts every batch —
before, during and after every calibration swap, truncation re-runs
included — stays BIT-identical to the eager reference.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import executor, flow as F
from repro.core.enumeration import enumerate_plans
from repro.core.operators import Hints, Source
from repro.core.record import Schema, batch_from_dict

KEY_DOMAIN = 6  # join/group key values in [0, KEY_DOMAIN)


class _Gen:
    def __init__(self, seed: int, max_ops: int = 5):
        self.rng = np.random.default_rng(seed)
        self.max_ops = max_ops
        self.fresh = 0          # unique-name counter (fields + sources)
        self.sources: list = []  # (name, schema, is_key_unique)

    # -- naming ---------------------------------------------------------------
    def _name(self, prefix: str) -> str:
        self.fresh += 1
        return f"{prefix}{self.fresh}"

    # -- sources --------------------------------------------------------------
    def _new_source(self, n_fields: int, rows: int, unique_key: bool):
        name = self._name("S")
        fields = {self._name("k"): np.int64}  # field 0 is the key column
        for _ in range(n_fields - 1):
            fields[self._name("f")] = np.int64
        schema = Schema.of(**fields)
        self.sources.append((name, schema, unique_key, rows))
        return F.source(name, schema, num_records=rows * 25)

    # -- UDF factories (closures: the analyzers see only the black box) ------
    def _map_modify(self, schema):
        live = list(schema.fields)
        target = live[self.rng.integers(len(live))]
        reads = [live[i] for i in self.rng.choice(
            len(live), size=min(len(live), int(self.rng.integers(1, 3))),
            replace=False)]
        mult = int(self.rng.integers(1, 4))
        off = int(self.rng.integers(-3, 4))

        def udf(ir, out):
            val = ir.get(target) * 0
            for r in reads:
                val = val + ir.get(r)
            out.emit(ir.copy().set(target, val * mult + off))

        udf.__name__ = f"mod_{target}"
        return udf

    def _map_filter(self, schema):
        live = list(schema.fields)
        reads = [live[i] for i in self.rng.choice(
            len(live), size=min(len(live), int(self.rng.integers(1, 3))),
            replace=False)]
        mod = int(self.rng.integers(2, 4))
        keep = int(self.rng.integers(0, mod))

        def udf(ir, out):
            val = ir.get(reads[0]) * 0
            for r in reads:
                val = val + ir.get(r)
            out.emit(ir.copy(), where=(val % mod) == keep)

        udf.__name__ = "filt_" + "_".join(reads)
        return udf

    def _map_add(self, schema):
        live = list(schema.fields)
        reads = [live[i] for i in self.rng.choice(
            len(live), size=min(len(live), int(self.rng.integers(1, 3))),
            replace=False)]
        new = self._name("g")

        def udf(ir, out):
            val = ir.get(reads[0]) * 0
            for r in reads:
                val = val + ir.get(r)
            out.emit(ir.copy().set(new, val * 2 + 1))

        udf.__name__ = f"add_{new}"
        return udf

    def _reduce_agg(self, schema, key):
        """Decomposable aggregation: keys + a random mix of aggregates."""
        live = [f for f in schema.fields]
        a = live[self.rng.integers(len(live))]
        b = live[self.rng.integers(len(live))]
        o1, o2, o3 = self._name("a"), self._name("a"), self._name("a")
        kind = int(self.rng.integers(0, 3))

        if kind == 0:  # plain aggregates of input columns
            def udf(g, out):
                out.emit(g.keys().set(o1, g.sum(a)).set(o2, g.max(b))
                         .set(o3, g.count()))
        elif kind == 1:  # aggregate of a derived per-record expression
            def udf(g, out):
                out.emit(g.keys()
                         .set(o1, g.sum(g.get(a) * 2 + g.get(b)))
                         .set(o2, g.min(b)))
        else:  # arithmetic ON aggregates (range + exact integer mean)
            def udf(g, out):
                out.emit(g.keys().set(o1, g.max(a) - g.min(a))
                         .set(o2, g.mean(b)))

        udf.__name__ = f"agg_{o1}"
        return udf

    def _reduce_passthrough(self, schema, key):
        live = list(schema.fields)
        a = live[self.rng.integers(len(live))]
        thr = int(self.rng.integers(-2, 3))

        def udf(g, out):
            out.emit_records(where=g.any(g.get(a) > thr))

        udf.__name__ = f"keep_{a}"
        return udf

    def _cogroup_udf(self, lschema, rschema):
        a = list(lschema.fields)[self.rng.integers(len(lschema.fields))]
        b = list(rschema.fields)[self.rng.integers(len(rschema.fields))]
        o1, o2 = self._name("a"), self._name("a")

        def udf(gl, gr, out):
            out.emit(gl.keys().set(o1, gl.sum(a) + gr.sum(b))
                     .set(o2, gl.count() - gr.count()))

        udf.__name__ = f"cg_{o1}"
        return udf

    # -- flow assembly --------------------------------------------------------
    def build(self):
        node = self._new_source(int(self.rng.integers(2, 4)),
                                rows=int(self.rng.integers(24, 40)),
                                unique_key=False)
        n_ops = int(self.rng.integers(2, self.max_ops + 1))
        for _ in range(n_ops):
            schema = node.out_schema
            choice = self.rng.random()
            if choice < 0.20:
                node = F.map_(node, self._map_modify(schema))
            elif choice < 0.36:
                node = F.map_(node, self._map_filter(schema))
            elif choice < 0.46:
                node = F.map_(node, self._map_add(schema))
            elif choice < 0.54:  # WITH-TIES top-k (deterministic multiset)
                nk = min(len(schema.fields), int(self.rng.integers(1, 3)))
                key = [schema.fields[i] for i in self.rng.choice(
                    len(schema.fields), size=nk, replace=False)]
                node = F.limit_(node, k=int(self.rng.integers(2, 12)),
                                key=key, name=self._name("lim"))
            elif choice < 0.68:
                key = [schema.fields[self.rng.integers(len(schema.fields))]]
                if self.rng.random() < 0.6:
                    udf = self._reduce_agg(schema, key)
                else:
                    udf = self._reduce_passthrough(schema, key)
                node = F.reduce_(node, key, udf,
                                 hints=Hints(distinct_keys=KEY_DOMAIN))
            elif choice < 0.80:  # join a fresh dimension source
                right = self._new_source(2, rows=KEY_DOMAIN, unique_key=True)
                lk = schema.fields[self.rng.integers(len(schema.fields))]
                rk = right.out_schema.fields[0]
                hints = Hints(pk_side="right") if self.rng.random() < 0.7 \
                    else Hints()
                node = F.match(node, right, [lk], [rk], hints=hints)
            elif choice < 0.88:  # anti join against a fresh exclusion list
                right = self._new_source(
                    2, rows=int(self.rng.integers(2, KEY_DOMAIN + 2)),
                    unique_key=self.rng.random() < 0.5)
                lk = schema.fields[self.rng.integers(len(schema.fields))]
                rk = right.out_schema.fields[0]
                node = F.match(node, right, [lk], [rk], anti=True,
                               name=self._name("anti"))
            elif choice < 0.94:  # cross with a single-record source
                right = self._new_source(2, rows=1, unique_key=False)
                node = F.cross(node, right)
            else:  # cogroup with a fresh source on the key columns
                right = self._new_source(2, rows=int(self.rng.integers(8, 16)),
                                         unique_key=False)
                lk = schema.fields[0]
                rk = right.out_schema.fields[0]
                node = F.cogroup(node, right, [lk], [rk],
                                 self._cogroup_udf(schema, right.out_schema))
        return node

    def bindings(self, seed: int, drift: float = 0.0) -> dict:
        """Random bindings; `drift` in [0, 1] shifts the per-batch
        distributions (the adaptive-statistics drift mode): keys collapse
        toward one hot value (fewer groups, skewed join fanout) and values
        snap toward multiples of 6 (flipping the pass rates of the
        generated `% mod` filters for mod 2 and 3) with probability
        `drift`.  `drift=0` reproduces the stationary generator exactly."""
        rng = np.random.default_rng(seed)
        out = {}
        for name, schema, unique_key, rows in self.sources:
            cols = {}
            for i, f in enumerate(schema.fields):
                if i == 0 and unique_key:
                    cols[f] = np.arange(KEY_DOMAIN, dtype=np.int64)
                elif i == 0:
                    keys = rng.integers(0, KEY_DOMAIN, rows)
                    if drift:
                        keys = np.where(rng.random(rows) < drift, 0, keys)
                    cols[f] = keys
                else:
                    n = rows if not unique_key else KEY_DOMAIN
                    vals = rng.integers(-5, 9, n)
                    if drift:
                        vals = np.where(rng.random(n) < drift,
                                        (vals // 6) * 6, vals)
                    cols[f] = vals
            out[name] = batch_from_dict(cols)
        return out


def random_flow(seed: int, max_ops: int = 5):
    """(flow_root, make_bindings(seed, drift=0.0) -> dict) for one seed."""
    g = _Gen(seed, max_ops=max_ops)
    root = g.build()
    return root, g.bindings


def adversarial_hints(root, seed: int, factor: float = 100.0):
    """Rebuild `root` with every COST hint perturbed by up to `factor`x in a
    seeded random direction — underestimates included, the direction whose
    compaction capacities overrun at runtime.  Execution-semantic hints
    (`pk_side`, which selects the executor) are left alone: the adversary
    lies about statistics, not about the data's key structure."""
    rng = np.random.default_rng(seed)

    def jitter():
        return float(factor ** rng.uniform(-1.0, 1.0))

    def perturb(h: Hints) -> Hints:
        new = {}
        if h.selectivity is not None:
            new["selectivity"] = h.selectivity * jitter()
        if h.distinct_keys is not None:
            new["distinct_keys"] = max(1, round(h.distinct_keys * jitter()))
        if h.join_fanout is not None:
            new["join_fanout"] = h.join_fanout * jitter()
        if h.group_selectivity is not None:
            new["group_selectivity"] = h.group_selectivity * jitter()
        new["cpu_flops_per_record"] = h.cpu_flops_per_record * jitter()
        return dataclasses.replace(h, **new)

    def rebuild(n):
        kids = [rebuild(c) for c in n.children]
        if isinstance(n, Source):
            return n
        out = n.with_children(*kids)
        return dataclasses.replace(out, hints=perturb(out.hints))

    return rebuild(root)


def assert_adaptive_identical(root, make_bindings, seed: int,
                              n_stationary: int = 4, n_drifted: int = 6,
                              drift: float = 0.7, **compile_kwargs):
    """Serve a drifting workload through an adaptive CompiledPlan and assert
    EVERY batch — across calibration swaps and truncation re-runs — is
    bit-identical (row multiset, no tolerance) to the eager reference on
    the same batch.  Aggressive thresholds force the feedback loop to act
    within a short serve; returns the number of swaps performed.  Extra
    kwargs pass through to `compile_plan` (e.g. `use_megakernel`)."""
    from repro.core.pipeline import (AdaptiveConfig, ExecutableCache,
                                     compile_plan)

    cfg = AdaptiveConfig(check_every=2, patience=1, drift_high=0.6,
                         drift_low=0.3, min_drift_rows=0.0,
                         replan_max_plans=400)
    cp = compile_plan(root, cache=ExecutableCache(), adaptive=cfg,
                      **compile_kwargs)
    for t in range(n_stationary + n_drifted):
        b = make_bindings(seed + 37 * t,
                          drift=0.0 if t < n_stationary else drift)
        got = canonical_rows(cp.run(b))
        ref = canonical_rows(executor.execute(root, b))
        assert got == ref, (
            f"adaptive serve diverged from eager on batch {t} "
            f"(swaps so far: {cp.swaps}):\n" + root.pretty())
    return cp.swaps


def canonical_rows(batch) -> list:
    """Valid rows as a sorted list of tuples with fields aligned BY NAME
    (schema field order is not semantic — rotations reorder columns), values
    bit-exact (no tolerance)."""
    b = batch.to_numpy().compact()
    fields = sorted(b.fields)
    rows = list(zip(*[np.asarray(b.columns[f]).tolist() for f in fields]))
    return sorted(rows, key=lambda t: tuple(repr(x) for x in t))


def assert_closure_identical(root, bindings: dict, max_plans: int = 600):
    """Every plan in the rewrite closure — splits included — must be
    BIT-identical (multiset of rows, no tolerance) to the unoptimized eager
    execution.  Returns the number of plans checked and how many were split."""
    ref_batch = executor.execute(root, bindings)
    ref = canonical_rows(ref_batch)
    plans = enumerate_plans(root, max_plans=max_plans)
    assert any(p.canonical() == root.canonical() for p in plans)
    n_split = 0
    for p in plans:
        if ".pre" in p.canonical():
            n_split += 1
        got_batch = executor.execute(p, bindings)
        assert set(got_batch.fields) == set(ref_batch.fields)
        got = canonical_rows(got_batch)
        assert got == ref, (
            "rewritten plan diverges from the eager reference:\n"
            + p.pretty() + "\nvs original\n" + root.pretty())
    return len(plans), n_split
