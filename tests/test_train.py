"""Training substrate: optimizer, microbatching, compression, checkpointing,
fault-tolerant supervisor, elastic re-shard, data pipeline determinism."""

import os
import shutil
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import ModelConfig, make_model
from repro.train import checkpoint as ckpt
from repro.train.fault import Supervisor
from repro.train.optimizer import AdamWConfig, init_opt_state, lr_at
from repro.train.train_step import TrainConfig, make_train_step

CFG = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                  dtype="float32")


@pytest.fixture(scope="module")
def model_and_params():
    m = make_model(CFG)
    params = m.init(jax.random.key(0))
    return m, params, init_opt_state(params)


def _batch(step, b=8, t=33, vocab=32):
    rng = np.random.default_rng(step)
    return {"tokens": jnp.asarray(rng.integers(0, vocab, (b, t)))}


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, 10)) - 1e-3) < 1e-9
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-3)


def test_loss_decreases(model_and_params):
    m, params, opt = model_and_params
    step_fn = jax.jit(make_train_step(
        m, TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=5,
                                       total_steps=100))))
    first = last = None
    for s in range(25):
        params, opt, metrics = step_fn(params, opt, _batch(s), s)
        if s == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.5


def test_microbatch_equals_full_batch(model_and_params):
    m, params, opt = model_and_params
    t1 = jax.jit(make_train_step(m, TrainConfig(opt=AdamWConfig())))
    t4 = jax.jit(make_train_step(m, TrainConfig(opt=AdamWConfig(),
                                                microbatches=4)))
    b = _batch(0)
    p1, _, m1 = t1(params, opt, b, 0)
    p4, _, m4 = t4(params, opt, b, 0)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-4
    for a, c in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), atol=5e-3)


def test_compressed_grads_roundtrip():
    from repro.train.compression import dequantize_int8, quantize_int8

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32)) * 3.0
    q, s = quantize_int8(x, jax.random.key(0))
    back = dequantize_int8(q, s)
    err = float(jnp.abs(back - x).max())
    assert err <= float(s) * 1.01  # stochastic rounding: within one step
    # unbiasedness of stochastic rounding (many keys)
    outs = [dequantize_int8(*quantize_int8(x, jax.random.key(i)))
            for i in range(20)]
    bias = float(jnp.abs(sum(outs) / len(outs) - x).mean())
    assert bias < float(s) * 0.3


def test_checkpoint_roundtrip_and_gc(tmp_path, model_and_params):
    m, params, opt = model_and_params
    d = str(tmp_path / "ck")
    for step in (5, 10, 15, 20):
        ckpt.save_checkpoint(d, step, {"params": params, "opt": opt},
                             wait=True)
    assert ckpt.latest_step(d) == 20
    ckpt.keep_last(d, 2)
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                   if x.startswith("step_"))
    assert steps == [15, 20]
    tree, step = ckpt.restore_checkpoint(d, {"params": params, "opt": opt})
    assert step == 20
    for a, b in zip(jax.tree.leaves(tree["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_restart_and_retry(tmp_path, model_and_params):
    m, params, opt = model_and_params
    step_fn = jax.jit(make_train_step(m, TrainConfig(opt=AdamWConfig())))
    d = str(tmp_path / "sup")
    sup = Supervisor(ckpt_dir=d, ckpt_every=5)
    state = {"params": params, "opt": opt, "step": 0}
    state, _ = sup.run(state=state, train_step=step_fn, batch_fn=_batch,
                       num_steps=8, log_every=0, log=lambda *a: None)
    assert state["step"] == 8

    # simulated transient failures: first two calls raise
    fails = {"n": 2}

    def flaky(params, opt, batch, step):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise RuntimeError("simulated node failure")
        return step_fn(params, opt, batch, step)

    state2 = {"params": params, "opt": opt, "step": 0}
    state2, wd = sup.run(state=state2, train_step=flaky, batch_fn=_batch,
                         num_steps=12, log_every=0, log=lambda *a: None)
    assert state2["step"] == 12  # resumed from ckpt and completed


def test_straggler_watchdog():
    from repro.train.fault import StragglerWatchdog

    events = []
    wd = StragglerWatchdog(deadline_s=0.5,
                           on_straggler=lambda s, d: events.append(s))
    wd.observe(1, 0.1)
    wd.observe(2, 1.2)
    assert events == [2] and wd.events == [(2, 1.2)]


ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    sys.path.insert(0, %r)
    import jax, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import ModelConfig, make_model
    from repro.train import checkpoint as ckpt
    from repro.parallel.sharding import validated_pspecs

    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
                      dtype="float32")
    m = make_model(cfg)
    mesh = jax.make_mesh((%d,), ("data",))
    params = m.init(jax.random.key(0))
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s),
                             validated_pspecs(jax.eval_shape(lambda: params),
                                              mesh))
    params = jax.tree.map(jax.device_put, params, shardings)
    d = %r
    if %r == "save":
        ckpt.save_checkpoint(d, 7, {"params": params}, wait=True)
    else:
        tree, step = ckpt.restore_checkpoint(d, {"params": params},
                                             shardings={"params": shardings})
        assert step == 7
        l = jax.tree.leaves(tree["params"])[0]
        assert len(l.sharding.device_set) == %d
    print("OK")
""")


def test_elastic_reshard_across_meshes(tmp_path):
    """Checkpoint saved on an 8-device mesh restores onto a 4-device mesh."""
    d = str(tmp_path / "elastic")
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    for devs, mode in ((8, "save"), (4, "load")):
        script = ELASTIC_SCRIPT % (devs, src, devs, d, mode, devs)
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=240)
        assert r.returncode == 0, r.stderr[-2000:]
        assert "OK" in r.stdout


def test_data_pipeline_deterministic():
    from repro.data.pipeline import TokenPipeline

    p1 = TokenPipeline(vocab=128, batch=4, seq=16, seed=3, docs_per_step=512)
    p2 = TokenPipeline(vocab=128, batch=4, seq=16, seed=3, docs_per_step=512)
    b1, b2 = p1(11), p2(11)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = p1(12)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
