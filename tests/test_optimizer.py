"""Interleaved branch-and-bound optimizer vs. the two-phase reference.

The acceptance bar for the interleaved search: on every evaluation flow it
must return the SAME best plan — identical operator order and total cost
(within 1e-9) — as exhaustively pricing every enumerated flow.  Pruning may
only skip flows that provably cannot win.
"""

import numpy as np
import pytest

import repro.core.optimizer as optimizer_mod
from repro.configs import flows
from repro.core import flow as F
from repro.core.enumeration import PlanSpaceExceeded, enumerate_plans
from repro.core.operators import Hints, commute_id, struct_id
from repro.core.optimizer import optimize, optimize_two_phase
from repro.core.physical import Ctx
from repro.core.record import Schema


def _assert_same_best(root, **kw):
    a = optimize(root, Ctx(dop=32), **kw)
    b = optimize_two_phase(root, Ctx(dop=32), **kw)
    assert a.best.flow.op_names() == b.best.flow.op_names(), \
        (a.best.order(), b.best.order())
    assert abs(a.best.cost - b.best.cost) <= 1e-9
    return a, b


@pytest.mark.parametrize("name", list(flows.FLOWS))
@pytest.mark.parametrize("include_commutes", [True, False])
def test_same_best_plan_as_two_phase(name, include_commutes):
    root, _ = flows.FLOWS[name]()
    a, b = _assert_same_best(root, include_commutes=include_commutes)
    # the searches cover the same logical plan space
    assert a.num_enumerated == b.num_enumerated


def test_pruning_skips_but_never_misses():
    root, _ = flows.FLOWS["q7"]()
    a = optimize(root, Ctx(dop=32))
    assert a.num_pruned > 0                      # the bound actually bites
    assert len(a.ranked) + a.num_pruned == a.num_enumerated
    assert a.ranked[0].cost == min(r.cost for r in a.ranked)


def test_join_tree_same_best_plan():
    for builder, n in ((flows.star_join, 5), (flows.chain_join, 6)):
        _assert_same_best(builder(n), include_commutes=False,
                          max_plans=100_000)
        _assert_same_best(builder(n), include_commutes=True,
                          max_plans=100_000)


def test_unary_group_search_matches_closure():
    """Force the group-lattice fast path on small unary flows and compare
    against the materializing reference, including order-sensitive stats
    (filters + reduces with and without distinct-key hints)."""
    old = optimizer_mod.GROUP_SEARCH_THRESHOLD
    optimizer_mod.GROUP_SEARCH_THRESHOLD = 0
    try:
        root, _ = flows.textmining()
        _assert_same_best(root)

        rng = np.random.default_rng(7)
        fields = ["A", "B", "C", "D"]
        for trial in range(15):
            sch = Schema.of(**{f: np.int64 for f in fields})
            node = F.source("I", sch,
                            num_records=int(rng.integers(1000, 1_000_000)))
            for i in range(int(rng.integers(3, 6))):
                tgt = fields[int(rng.integers(0, 4))]
                if rng.random() < 0.7:
                    def udf(ir, out, tgt=tgt):
                        out.emit(ir.copy().set(tgt, ir.get(tgt) + 1))

                    udf.__name__ = f"m{trial}_{i}"
                    node = F.map_(node, udf, name=f"M{i}", hints=Hints(
                        selectivity=float(rng.uniform(0.1, 1.0))))
                else:
                    def udf(g, out, tgt=tgt):
                        out.emit_records(where=g.any(g.get(tgt) > 0))

                    udf.__name__ = f"r{trial}_{i}"
                    node = F.reduce_(node, [fields[int(rng.integers(0, 4))]],
                                     udf, name=f"R{i}", hints=Hints(
                        group_selectivity=float(rng.uniform(0.2, 0.9))))
            _assert_same_best(node)
    finally:
        optimizer_mod.GROUP_SEARCH_THRESHOLD = old


def test_group_search_handles_factorial_spaces():
    """map-chain-9 has 9! = 362880 orderings; the group search must price it
    through the subset lattice without materializing them."""
    chain = flows.map_chain(9)
    res = optimize(chain, Ctx(dop=8))
    assert res.num_enumerated == 362_880
    # identical maps: every order costs the same, the original order wins
    assert res.best.flow.op_names() == chain.op_names()


def test_plan_space_exceeded_carries_partial_count():
    chain = flows.map_chain(6)  # 720 orderings
    with pytest.raises(PlanSpaceExceeded) as ei:
        enumerate_plans(chain, max_plans=100)
    assert ei.value.limit == 100
    assert ei.value.count == 100
    assert "100" in str(ei.value)
    # the optimizer's closure path propagates it too
    with pytest.raises(PlanSpaceExceeded):
        optimize(chain, Ctx(dop=8), max_plans=100)
    # and PlanSpaceExceeded still is a RuntimeError for legacy callers
    assert issubclass(PlanSpaceExceeded, RuntimeError)


def _brute_force_closure(flow, cap=5000) -> set:
    """Reference enumeration: raw local_rewrites applied at every position,
    no hash-consing, no commute-class quotient."""
    from repro.core.reorder import local_rewrites

    def rewrites_everywhere(tree):
        yield from local_rewrites(tree)
        for i, child in enumerate(tree.children):
            for sub in rewrites_everywhere(child):
                kids = list(tree.children)
                kids[i] = sub
                try:
                    yield tree.with_children(*kids)
                except (ValueError, KeyError):
                    continue

    seen = {flow.canonical()}
    work = [flow]
    while work:
        cur = work.pop()
        for t in rewrites_everywhere(cur):
            c = t.canonical()
            if c not in seen:
                assert len(seen) < cap
                seen.add(c)
                work.append(t)
    return seen


@pytest.mark.parametrize("builder,n", [
    (flows.chain_join, 4), (flows.chain_join, 5), (flows.star_join, 4)])
def test_closure_matches_brute_force_joins(builder, n):
    flow = builder(n)
    fast = {p.canonical() for p in enumerate_plans(flow, max_plans=100_000)}
    assert fast == _brute_force_closure(flow)


def test_closure_matches_brute_force_cross():
    """Regression: both conjugate rotations of a Cross (where, unlike Match,
    key locality pins nothing) must be generated — a side=1 key mix-up in
    the rewrite engine once suppressed half the cross plan space."""
    import numpy as np

    from repro.core.record import Schema

    rels = [F.source(f"R{i}", Schema.of(**{f"x{i}": np.int64}),
                     num_records=10 * (i + 1)) for i in range(3)]
    flow = F.cross(F.cross(rels[0], rels[1], name="CA"), rels[2], name="CB")
    fast = {p.canonical() for p in enumerate_plans(flow, max_plans=100_000)}
    ref = _brute_force_closure(flow)
    assert fast == ref
    # left-deep start as well as right-deep
    flow2 = F.cross(rels[0], F.cross(rels[1], rels[2], name="CA2"),
                    name="CB2")
    fast2 = {p.canonical() for p in enumerate_plans(flow2, max_plans=100_000)}
    assert fast2 == _brute_force_closure(flow2)


def test_structural_ids_follow_canonical():
    """Hash-consed ids agree with the canonical string exactly."""
    root, _ = flows.FLOWS["q7"]()
    plans = enumerate_plans(root, include_commutes=True)
    by_sid = {}
    by_can = {}
    for p in plans:
        by_sid.setdefault(struct_id(p), set()).add(p.canonical())
        by_can.setdefault(p.canonical(), set()).add(struct_id(p))
    assert all(len(v) == 1 for v in by_sid.values())
    assert all(len(v) == 1 for v in by_can.values())
    # commute ids collapse argument order: q7 has 41 distinct pure
    # reorderings; aggregation splitting strictly enlarges the space
    # (AggRevenue is decomposable) without disturbing the reordering core
    reorder_only = enumerate_plans(root, include_commutes=True,
                                   split_reduces=False)
    assert len({commute_id(p) for p in reorder_only}) == 41
    split_cids = {commute_id(p) for p in plans}
    assert {commute_id(p) for p in reorder_only} < split_cids
    assert any(".pre" in p.canonical() for p in plans)
