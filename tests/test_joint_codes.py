"""Regression tests for `executor.joint_codes` (the composite-key
factorizer shared by grouping, join and cogroup paths).

PR 1 removed a dead duplicate `lens` computation whose surviving version
crashed on scalar (0-d) key columns; scalars must code as one record."""

import numpy as np

from repro.core.executor import joint_codes


def test_joint_codes_basic_two_groups():
    (lc, rc), num = joint_codes([
        [np.array([1, 2, 1])], [np.array([2, 3])]])
    assert len(lc) == 3 and len(rc) == 2
    assert lc[0] == lc[2] != lc[1]          # equal keys, equal codes
    assert lc[1] == rc[0]                   # 2 codes equal across groups
    assert num == 3                          # domain {1, 2, 3}


def test_joint_codes_composite_keys():
    (codes,), num = joint_codes([
        [np.array([1, 1, 2]), np.array([10, 11, 10])]])
    assert len(set(codes.tolist())) == 3 == num


def test_joint_codes_scalar_column_regression():
    # a 0-d key column is a single record, and must join up with equal
    # keys in the other group
    (sc, rc), num = joint_codes([
        [np.int64(5)], [np.array([4, 5, 6])]])
    assert sc.shape == (1,)
    assert sc[0] == rc[1]
    assert num == 3

    # scalar composite keys too
    (sc2,), num2 = joint_codes([[np.int64(1), np.int64(2)]])
    assert sc2.shape == (1,) and num2 == 1


def test_joint_codes_empty_group():
    (ec, rc), num = joint_codes([
        [np.array([], dtype=np.int64)], [np.array([7, 7])]])
    assert ec.shape == (0,)
    assert len(rc) == 2 and rc[0] == rc[1]
    assert num == 1
