"""Property tests for the prefix-sum masked compaction (DESIGN.md §8).

`MaskedBatch.compact` is load-bearing for order-aware execution: it must
keep exactly the valid rows (up to capacity), in their original relative
order (STABILITY — what lets `order` metadata survive stage boundaries),
across shrink / same-size / grow targets on the bucket ladder.  Seeded
sweeps in the style of tests/test_prune.py; no hypothesis dependency.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.masked import MaskedBatch, bucket_capacity, order_prefix

SEEDS = range(12)


def _random_batch(rng, cap, valid_frac, sort_col=False):
    a = rng.integers(-1000, 1000, cap)
    if sort_col:
        a = np.sort(a)
    cols = {
        "a": jnp.asarray(a),
        "b": jnp.asarray(rng.integers(-5, 5, cap)),
        "f": jnp.asarray(rng.uniform(-1, 1, cap).astype(np.float32)),
    }
    valid = rng.random(cap) < valid_frac
    return MaskedBatch(cols, jnp.asarray(valid),
                       order=("a",) if sort_col else ())


def _valid_rows(b: MaskedBatch):
    v = np.asarray(b.valid)
    return [tuple(np.asarray(b.columns[f])[v].tolist())
            for f in sorted(b.columns)]


@pytest.mark.parametrize("seed", SEEDS)
def test_compact_preserves_valid_rows_and_is_stable(seed):
    rng = np.random.default_rng(seed)
    cap = int(rng.choice([8, 64, 256, 1024]))
    b = _random_batch(rng, cap, valid_frac=float(rng.uniform(0, 1)))
    nv = int(np.asarray(b.valid).sum())
    target = bucket_capacity(max(nv, 1))
    c = b.compact(target)
    assert c.capacity == target
    # exact same row sequence (not just multiset: stability) per column
    before = _valid_rows(b)
    after = _valid_rows(c)
    assert after == [col[:target] for col in before]


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("target", ["shrink", "same", "grow"])
def test_compact_across_capacity_buckets(seed, target):
    rng = np.random.default_rng(seed)
    cap = 128
    b = _random_batch(rng, cap, valid_frac=0.3)
    nv = int(np.asarray(b.valid).sum())
    newcap = {"shrink": max(bucket_capacity(max(nv, 1)), 8),
              "same": cap, "grow": 4 * cap}[target]
    c = b.compact(newcap)
    assert c.capacity == newcap
    assert int(np.asarray(c.valid).sum()) == min(nv, newcap)
    # valid rows form a prefix after compaction
    v = np.asarray(c.valid)
    assert not v[min(nv, newcap):].any()
    assert v[:min(nv, newcap)].all()
    assert _valid_rows(c) == [col[:newcap] for col in _valid_rows(b)]


@pytest.mark.parametrize("seed", SEEDS)
def test_compact_preserves_order_metadata_and_sortedness(seed):
    rng = np.random.default_rng(seed)
    b = _random_batch(rng, 256, valid_frac=0.4, sort_col=True)
    c = b.compact(128)
    assert c.order == ("a",)
    av = np.asarray(c.columns["a"])[np.asarray(c.valid)]
    assert (np.diff(av) >= 0).all(), "stable compact must keep sortedness"


def test_compact_truncation_keeps_first_rows():
    # documented contract: a too-small capacity drops the TAIL valid rows
    cols = {"a": jnp.arange(16)}
    b = MaskedBatch(cols, jnp.ones(16, bool))
    c = b.compact(8)
    assert np.asarray(c.valid).all()
    assert np.asarray(c.columns["a"]).tolist() == list(range(8))


def test_order_prefix_breaks_on_write_and_projection():
    assert order_prefix(("a", "b", "c"), {"a", "b", "c"}) == ("a", "b", "c")
    assert order_prefix(("a", "b", "c"), {"a", "c"}) == ("a",)
    assert order_prefix(("a", "b"), {"a", "b"}, writes={"b"}) == ("a",)
    assert order_prefix(("a", "b"), {"a", "b"}, writes={"a"}) == ()
