"""SCA analyzers on the paper's Sec. 3/5 example functions + safety checks."""

import numpy as np
import pytest

from repro.core.record import Schema
from repro.core.sca import analyze_udf
from repro.core.sca import bytecode as bc
from repro.core.sca import jaxpr_sca as jx
from repro.core.udf import Card, KatEmit

SCHEMA = Schema.of(A=np.int64, B=np.int64)


def f1(ir, out):  # B := |B|     (paper Sec. 3)
    out.emit(ir.copy().set("B", abs(ir.get("B"))))


def f2(ir, out):  # filter A >= 0
    out.emit(ir.copy(), where=ir.get("A") >= 0)


def f3(ir, out):  # A := A + B
    out.emit(ir.copy().set("A", ir.get("A") + ir.get("B")))


@pytest.mark.parametrize("mode", ["bytecode", "jaxpr"])
def test_paper_sec3_read_write_sets(mode):
    p1 = analyze_udf(f1, "map", [SCHEMA], mode=mode)
    p2 = analyze_udf(f2, "map", [SCHEMA], mode=mode)
    p3 = analyze_udf(f3, "map", [SCHEMA], mode=mode)
    assert p1.reads == {"B"} and p1.writes == {"B"}
    assert p2.reads == {"A"} and p2.writes == set()
    assert p3.reads == {"A", "B"} and p3.writes == {"A"}
    assert p2.card is Card.AT_MOST_ONE
    assert p1.card is Card.ONE and p3.card is Card.ONE
    assert p2.filter_fields == {"A"}


def test_explicit_copy_not_a_write():
    def copier(ir, out):
        out.emit(ir.copy().set("A", ir.get("A")))

    p = analyze_udf(copier, "map", [SCHEMA], mode="jaxpr")
    assert "A" not in p.writes


def test_implicit_projection_drops():
    def proj(ir, out):
        b = ir.get("B")
        from repro.core.udf import empty

        out.emit(empty().set("B2", b * 2))

    p = analyze_udf(proj, "map", [SCHEMA], mode="jaxpr")
    assert p.adds == {"B2"}
    assert {"A", "B"} <= p.drops
    assert not p.implicit_copy


def test_kat_classification():
    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("B")))

    def passthrough_filter(g, out):
        out.emit_records(where=g.any(g.get("B") > 0))

    pa = analyze_udf(agg, "reduce", [SCHEMA], key=("A",), mode="jaxpr")
    assert pa.kat_emit is KatEmit.PER_GROUP
    assert "A" in pa.reads  # keys always read
    pf = analyze_udf(passthrough_filter, "reduce", [SCHEMA], key=("A",),
                     mode="jaxpr")
    assert pf.kat_emit is KatEmit.PASSTHROUGH_FILTER
    assert pf.writes == set()


def test_bytecode_is_conservative_superset_of_jaxpr():
    """Safety through conservatism (Sec. 5): the static estimate must be a
    superset of the exact (traced) property sets."""
    for udf in (f1, f2, f3):
        pb = analyze_udf(udf, "map", [SCHEMA], mode="bytecode")
        pj = analyze_udf(udf, "map", [SCHEMA], mode="jaxpr")
        assert pb.is_superset_of(pj), udf.__name__


def test_schema_dependent_detection():
    def dynamic(ir, out):
        cols = ir.fields  # schema reflection
        out.emit(ir.copy())

    assert bc.is_schema_dependent(dynamic)
    assert not bc.is_schema_dependent(f1)
    p = analyze_udf(dynamic, "map", [SCHEMA], mode="auto")
    assert p.schema_dependent


def test_dynamic_set_name_rejected():
    def bad(ir, out):
        name = "A" if len(ir.fields) else "B"
        out.emit(ir.copy().set(name, ir.get("A")))

    with pytest.raises(ValueError):
        bc.analyze(bad, ["A", "B"])


def test_match_keys_join_read_set():
    def join(l, r, out):
        out.emit(l.concat(r))

    s2 = Schema.of(K=np.int64, V=np.int64)
    p = analyze_udf(join, "match", [SCHEMA, s2], left_key=("A",),
                    right_key=("K",), mode="jaxpr")
    assert {"A", "K"} <= p.reads
