"""SCA analyzers on the paper's Sec. 3/5 example functions + safety checks."""

import numpy as np
import pytest

from repro.core.record import Schema
from repro.core.sca import analyze_udf
from repro.core.sca import bytecode as bc
from repro.core.sca import jaxpr_sca as jx
from repro.core.udf import Card, KatEmit

SCHEMA = Schema.of(A=np.int64, B=np.int64)


def f1(ir, out):  # B := |B|     (paper Sec. 3)
    out.emit(ir.copy().set("B", abs(ir.get("B"))))


def f2(ir, out):  # filter A >= 0
    out.emit(ir.copy(), where=ir.get("A") >= 0)


def f3(ir, out):  # A := A + B
    out.emit(ir.copy().set("A", ir.get("A") + ir.get("B")))


@pytest.mark.parametrize("mode", ["bytecode", "jaxpr"])
def test_paper_sec3_read_write_sets(mode):
    p1 = analyze_udf(f1, "map", [SCHEMA], mode=mode)
    p2 = analyze_udf(f2, "map", [SCHEMA], mode=mode)
    p3 = analyze_udf(f3, "map", [SCHEMA], mode=mode)
    assert p1.reads == {"B"} and p1.writes == {"B"}
    assert p2.reads == {"A"} and p2.writes == set()
    assert p3.reads == {"A", "B"} and p3.writes == {"A"}
    assert p2.card is Card.AT_MOST_ONE
    assert p1.card is Card.ONE and p3.card is Card.ONE
    assert p2.filter_fields == {"A"}


def test_explicit_copy_not_a_write():
    def copier(ir, out):
        out.emit(ir.copy().set("A", ir.get("A")))

    p = analyze_udf(copier, "map", [SCHEMA], mode="jaxpr")
    assert "A" not in p.writes


def test_implicit_projection_drops():
    def proj(ir, out):
        b = ir.get("B")
        from repro.core.udf import empty

        out.emit(empty().set("B2", b * 2))

    p = analyze_udf(proj, "map", [SCHEMA], mode="jaxpr")
    assert p.adds == {"B2"}
    assert {"A", "B"} <= p.drops
    assert not p.implicit_copy


def test_kat_classification():
    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("B")))

    def passthrough_filter(g, out):
        out.emit_records(where=g.any(g.get("B") > 0))

    pa = analyze_udf(agg, "reduce", [SCHEMA], key=("A",), mode="jaxpr")
    assert pa.kat_emit is KatEmit.PER_GROUP
    assert "A" in pa.reads  # keys always read
    pf = analyze_udf(passthrough_filter, "reduce", [SCHEMA], key=("A",),
                     mode="jaxpr")
    assert pf.kat_emit is KatEmit.PASSTHROUGH_FILTER
    assert pf.writes == set()


def test_bytecode_is_conservative_superset_of_jaxpr():
    """Safety through conservatism (Sec. 5): the static estimate must be a
    superset of the exact (traced) property sets."""
    for udf in (f1, f2, f3):
        pb = analyze_udf(udf, "map", [SCHEMA], mode="bytecode")
        pj = analyze_udf(udf, "map", [SCHEMA], mode="jaxpr")
        assert pb.is_superset_of(pj), udf.__name__


def test_schema_dependent_detection():
    def dynamic(ir, out):
        cols = ir.fields  # schema reflection
        out.emit(ir.copy())

    assert bc.is_schema_dependent(dynamic)
    assert not bc.is_schema_dependent(f1)
    p = analyze_udf(dynamic, "map", [SCHEMA], mode="auto")
    assert p.schema_dependent


def test_dynamic_set_name_rejected():
    def bad(ir, out):
        name = "A" if len(ir.fields) else "B"
        out.emit(ir.copy().set(name, ir.get("A")))

    with pytest.raises(ValueError):
        bc.analyze(bad, ["A", "B"])


def test_match_keys_join_read_set():
    def join(l, r, out):
        out.emit(l.concat(r))

    s2 = Schema.of(K=np.int64, V=np.int64)
    p = analyze_udf(join, "match", [SCHEMA, s2], left_key=("A",),
                    right_key=("K",), mode="jaxpr")
    assert {"A", "K"} <= p.reads


# ---------------------------------------------------------------------------
# Analyzer agreement over every exemplar UDF in the suite
# ---------------------------------------------------------------------------
def _exemplar_operators():
    """Every (udf, kind, in_schemas, key, left_key, right_key) exercised by
    the test suite: this module's exemplars, the four paper evaluation flows,
    and a sample of flowgen's generated tree flows."""
    from repro.configs import flows
    from repro.core.operators import (CoGroupOp, CrossOp, MapOp, MatchOp,
                                      ReduceOp)

    out = [(f1, "map", [SCHEMA], (), (), ()),
           (f2, "map", [SCHEMA], (), (), ()),
           (f3, "map", [SCHEMA], (), (), ())]

    def agg(g, out_):
        out_.emit(g.keys().set("s", g.sum("B")))

    out.append((agg, "reduce", [SCHEMA], ("A",), (), ()))

    roots = [builder()[0] for builder in flows.FLOWS.values()]
    import flowgen

    roots += [flowgen.random_flow(seed)[0] for seed in range(6)]
    for root in roots:
        for node in root.iter_nodes():
            if isinstance(node, MapOp):
                out.append((node.udf, "map", [node.child.out_schema],
                            (), (), ()))
            elif isinstance(node, ReduceOp):
                out.append((node.udf, "reduce", [node.child.out_schema],
                            node.key, (), ()))
            elif isinstance(node, MatchOp):
                out.append((node.udf, "match",
                            [node.left.out_schema, node.right.out_schema],
                            (), node.left_key, node.right_key))
            elif isinstance(node, CrossOp):
                out.append((node.udf, "cross",
                            [node.left.out_schema, node.right.out_schema],
                            (), (), ()))
            elif isinstance(node, CoGroupOp):
                out.append((node.udf, "cogroup",
                            [node.left.out_schema, node.right.out_schema],
                            (), node.left_key, node.right_key))
    return out


def test_jaxpr_sets_are_subsets_of_bytecode_sets():
    """Safety through conservatism on EVERY exemplar UDF: the bytecode
    analyzer's static estimates must be supersets of the exact (traced)
    jaxpr sets — read, write, add and filter-field."""
    checked = 0
    for udf, kind, schemas, key, lk, rk in _exemplar_operators():
        kw = dict(key=key, left_key=lk, right_key=rk)
        try:
            pb = analyze_udf(udf, kind, schemas, mode="bytecode", **kw)
        except ValueError:
            # the bytecode analyzer REFUSES dynamic field names (paper
            # Sec. 5 assumption) instead of guessing — conservative, skip
            continue
        pj = analyze_udf(udf, kind, schemas, mode="jaxpr", **kw)
        name = getattr(udf, "__name__", "udf")
        assert pb.is_superset_of(pj), (name, pb, pj)
        assert pb.filter_fields >= pj.filter_fields, name
        checked += 1
    assert checked > 25  # the sweep actually covered the exemplar corpus


def test_decomposability_claims_match_eager_execution():
    """A decomposability claim from EITHER analyzer must survive the eager
    differential check (split vs unsplit on multiple partitions): the static
    candidate may be optimistic, but never execution-contradicted."""
    from repro.core.sca import decompose

    n_claims = 0
    for udf, kind, schemas, key, lk, rk in _exemplar_operators():
        if kind != "reduce":
            continue
        for mode in ("bytecode", "jaxpr"):
            try:
                p = analyze_udf(udf, kind, schemas, key=key, mode=mode)
            except ValueError:
                continue  # bytecode refusal (dynamic field names)
            if p.combine is None:
                continue
            n_claims += 1
            assert decompose.verify(udf, schemas[0], key, p.combine), \
                (getattr(udf, "__name__", "udf"), mode, p.combine)
    assert n_claims >= 6  # the corpus exercises real claims


def test_bytecode_candidate_is_verified_or_dropped():
    """A UDF the static scan would flag decomposable but whose semantics are
    NOT (aggregate argument depends on another aggregate) must come out of
    `analyze_udf` with no recipe — the differential check rejects it."""
    def sneaky(g, out):
        # straight-line, single keys()-projecting emit, only get/sum method
        # calls — the static scan proposes a recipe.  But the aggregate's
        # argument is scaled by the ORDER-DEPENDENT first element of the
        # batch, so shard-local partial sums do not compose.
        b = g.get("B")
        out.emit(g.keys().set("x", g.sum(b * b[0])))

    from repro.core.sca import bytecode as bc_mod

    static = bc_mod.analyze(sneaky, list(SCHEMA.fields), kat=True,
                            key_fields=("A",))
    assert static.combine is not None  # the static scan IS fooled...
    for mode in ("auto", "bytecode", "jaxpr"):
        p = analyze_udf(sneaky, "reduce", [SCHEMA], key=("A",), mode=mode)
        assert p.combine is None  # ...and the differential check rejects it
