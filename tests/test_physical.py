"""Cost model + physical optimizer unit behaviour."""

import numpy as np

from repro.core import flow as F
from repro.core.cost import estimate
from repro.core.operators import Hints
from repro.core.physical import Ctx, Props, best_physical, candidates
from repro.core.record import Schema


def _q15ish(li_rows, su_rows):
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=li_rows)
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64),
                  num_records=su_rows)
    return F.match(li, su, ["k"], ["sk"], name="J",
                   hints=Hints(pk_side="right")), li, su


def test_cardinality_estimates():
    j, li, su = _q15ish(1_000_000, 1_000)
    st = estimate(j)
    assert st.rows == 1_000_000  # FK side preserved under PK join

    def filt(ir, out):
        out.emit(ir.copy(), where=ir.get("v") > 0)

    m = F.map_(li, filt, name="F", hints=Hints(selectivity=0.1))
    assert estimate(m).rows == 100_000


def test_broadcast_wins_for_small_side():
    j, *_ = _q15ish(100_000_000, 1_000)
    plan = best_physical(j, Ctx(dop=32))
    assert plan.ship == ("forward", "broadcast")


def test_partition_wins_for_balanced_sides():
    j, *_ = _q15ish(50_000_000, 40_000_000)
    plan = best_physical(j, Ctx(dop=32))
    assert "broadcast" not in plan.ship


def test_interesting_property_reuse():
    """A Reduce on the same key downstream of a partitioned Match reuses the
    partitioning (forward, no second shuffle) — Volcano-style DP."""
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=50_000_000)
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64),
                  num_records=40_000_000)
    j = F.match(li, su, ["k"], ["sk"], name="J")

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    r = F.reduce_(j, ["k"], agg, name="R", hints=Hints(distinct_keys=100_000))
    plan = best_physical(r, Ctx(dop=32))
    assert plan.ship == ("forward",)          # reuses the join partitioning
    assert plan.local in ("sort", "reuse-sort")


def test_source_partitioning_respected():
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=10_000_000, partitioned_on=("k",))

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    r = F.reduce_(li, ["k"], agg, name="R")
    plan = best_physical(r, Ctx(dop=32))
    assert plan.ship == ("forward",)


def test_props_partition_semantics():
    p = Props(partitions=frozenset({frozenset({"a"})}), sort=("a", "b"))
    assert p.partitioned_on(frozenset({"a", "b"}))     # subset key co-located
    assert not p.partitioned_on(frozenset({"b"}))
    assert p.sorted_on(frozenset({"a"}))
    assert p.sorted_on(frozenset({"a", "b"}))
    assert not p.sorted_on(frozenset({"b"}))


def test_pareto_keeps_property_plans():
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=50_000_000)
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64),
                  num_records=1_000)
    j = F.match(li, su, ["k"], ["sk"], name="J", hints=Hints(pk_side="right"))
    cands = candidates(j, Ctx(dop=32))
    # broadcast is cheapest, but the partitioned variant must survive because
    # it offers co-located keys to downstream consumers
    assert len(cands) >= 2
    assert any(p.partitioned_on(frozenset({"k"})) for p in cands)
