"""Cost model + physical optimizer unit behaviour."""

import numpy as np

from repro.core import flow as F
from repro.core.cost import estimate
from repro.core.operators import Hints
from repro.core.physical import Ctx, Props, best_physical, candidates
from repro.core.record import Schema


def _q15ish(li_rows, su_rows):
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=li_rows)
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64),
                  num_records=su_rows)
    return F.match(li, su, ["k"], ["sk"], name="J",
                   hints=Hints(pk_side="right")), li, su


def test_cardinality_estimates():
    j, li, su = _q15ish(1_000_000, 1_000)
    st = estimate(j)
    assert st.rows == 1_000_000  # FK side preserved under PK join

    def filt(ir, out):
        out.emit(ir.copy(), where=ir.get("v") > 0)

    m = F.map_(li, filt, name="F", hints=Hints(selectivity=0.1))
    assert estimate(m).rows == 100_000


def test_broadcast_wins_for_small_side():
    j, *_ = _q15ish(100_000_000, 1_000)
    plan = best_physical(j, Ctx(dop=32))
    assert plan.ship == ("forward", "broadcast")


def test_partition_wins_for_balanced_sides():
    j, *_ = _q15ish(50_000_000, 40_000_000)
    plan = best_physical(j, Ctx(dop=32))
    assert "broadcast" not in plan.ship


def test_interesting_property_reuse():
    """A Reduce on the same key downstream of a partitioned Match reuses the
    partitioning (forward, no second shuffle) — Volcano-style DP."""
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=50_000_000)
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64),
                  num_records=40_000_000)
    j = F.match(li, su, ["k"], ["sk"], name="J")

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    r = F.reduce_(j, ["k"], agg, name="R", hints=Hints(distinct_keys=100_000))
    plan = best_physical(r, Ctx(dop=32))
    assert plan.ship == ("forward",)          # reuses the join partitioning
    assert plan.local in ("sort", "reuse-sort")


def test_source_partitioning_respected():
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=10_000_000, partitioned_on=("k",))

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    r = F.reduce_(li, ["k"], agg, name="R")
    plan = best_physical(r, Ctx(dop=32))
    assert plan.ship == ("forward",)


def test_props_partition_semantics():
    p = Props(partitions=frozenset({frozenset({"a"})}), sort=("a", "b"))
    assert p.partitioned_on(frozenset({"a", "b"}))     # subset key co-located
    assert not p.partitioned_on(frozenset({"b"}))
    assert p.sorted_on(frozenset({"a"}))
    assert p.sorted_on(frozenset({"a", "b"}))
    assert not p.sorted_on(frozenset({"b"}))


def test_pareto_keeps_property_plans():
    li = F.source("L", Schema.of(k=np.int64, v=np.float64),
                  num_records=50_000_000)
    su = F.source("S", Schema.of(sk=np.int64, nm=np.int64),
                  num_records=1_000)
    j = F.match(li, su, ["k"], ["sk"], name="J", hints=Hints(pk_side="right"))
    cands = candidates(j, Ctx(dop=32))
    # broadcast is cheapest, but the partitioned variant must survive because
    # it offers co-located keys to downstream consumers
    assert len(cands) >= 2
    assert any(p.partitioned_on(frozenset({"k"})) for p in cands)


# ---------------------------------------------------------------------------
# Sharding-aware layout costing (§12): dop ladder, latency term, subset keys
# ---------------------------------------------------------------------------
def test_dop_ladder_powers_of_two_plus_mesh():
    from repro.core.physical import dop_ladder

    assert dop_ladder(8) == (1, 2, 4, 8)
    assert dop_ladder(6) == (1, 2, 4, 6)
    assert dop_ladder(1) == (1,)


def test_collective_latency_term():
    from repro import hw
    from repro.core.physical import _t_broadcast, _t_latency, _t_shuffle

    c1, c8 = Ctx(dop=1), Ctx(dop=8)
    assert _t_latency(c1) == 0.0
    assert _t_shuffle(1e6, c1) == 0.0 and _t_broadcast(1e6, c1) == 0.0
    assert _t_latency(c8) == 3 * hw.TPU_V5E.ici_latency_s
    # even a zero-byte collective pays the launch latency at p > 1
    assert _t_shuffle(0.0, c8) == _t_latency(c8)
    assert _t_broadcast(0.0, c8) == _t_latency(c8)


def test_pk_join_small_build_side_broadcasts_at_mesh_dop():
    """Plan-choice acceptance: on the 8-way mesh the optimizer picks
    'broadcast the small PK side' over hash repartition of both sides."""
    big = F.source("Big", Schema.of(sk=np.int64, x=np.int64),
                   num_records=100_000_000)
    sup = F.source("Sup", Schema.of(jk=np.int64, sv=np.int64),
                   num_records=1_000)
    j = F.match(big, sup, ["sk"], ["jk"], name="J",
                hints=Hints(pk_side="right"))
    plan = best_physical(j, Ctx(dop=8))
    assert plan.ship == ("forward", "broadcast")
    assert plan.ship_keys == (None, None)


def test_chained_reduce_partitions_on_subset_key():
    """Reduce{a,b} below Reduce{a}: the inner shuffle hash-partitions on
    the single column 'a' (equal full key implies equal subset, same wire
    cost, reusable co-location), so the outer reduce forwards — the
    'keep the combiner's partitioning' layout of DESIGN.md §12."""
    S = Schema.of(a=np.int64, b=np.int64, v=np.int64)
    src = F.source("I", S, num_records=10_000_000)

    def agg2(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    r1 = F.reduce_(src, ["a", "b"], agg2, name="R1",
                   hints=Hints(distinct_keys=100_000))

    def agg1(g, out):
        out.emit(g.keys().set("t", g.sum("s")))

    r2 = F.reduce_(r1, ["a"], agg1, name="R2",
                   hints=Hints(distinct_keys=1_000))
    plan = best_physical(r2, Ctx(dop=8))
    assert plan.ship == ("forward",), plan.ship
    inner = plan.inputs[0]
    assert "partition" in inner.ship
    assert inner.ship_keys == (("a",),), inner.ship_keys
    assert inner.props.partitioned_on(frozenset({"a"}))


def test_optimize_layout_prices_dop():
    """dop is a costed decision: a tiny flow stays at dop=1 (collective
    latency dominates), a huge flow takes the full mesh."""
    from repro.core.optimizer import optimize_layout
    from repro.core.physical import dop_ladder

    S = Schema.of(a=np.int64, b=np.int64, v=np.int64)

    def agg(g, out):
        out.emit(g.keys().set("s", g.sum("v")))

    tiny = F.reduce_(F.source("T", S, num_records=2_000), ["a", "b"], agg,
                     name="RT", hints=Hints(distinct_keys=64))
    lt = optimize_layout(tiny, mesh_shards=8)
    huge = F.reduce_(F.source("H", S, num_records=500_000_000), ["a", "b"],
                     agg, name="RH", hints=Hints(distinct_keys=1_000_000))
    lh = optimize_layout(huge, mesh_shards=8)
    assert lt.dop == 1 and lh.dop == 8
    assert len(lt.per_dop) == len(dop_ladder(8))
    # per_dop is (dop, cost) pairs covering the ladder, best is the argmin
    costs = dict(lh.per_dop)
    assert costs[8] == min(costs.values())
    assert lh.best is lh.result.best  # .best is the winning RankedPlan
