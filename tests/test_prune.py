"""`physical._prune` / `Props.dominates` behaviour: dominated-plan
elimination, equal-cost ties, and a seeded property test that the sweep
never drops the overall-cheapest plan."""

import numpy as np

from repro.core.physical import CostVec, PhysPlan, Props, _prune


def _plan(cost: float, partitions=(), sort=()) -> PhysPlan:
    props = Props(partitions=frozenset(frozenset(g) for g in partitions),
                  sort=tuple(sort))
    return PhysPlan(node=None, props=props, node_cost=CostVec(net=cost))


def test_dominated_plan_eliminated():
    cheap_strong = _plan(1.0, partitions=[("k",)], sort=("k",))
    costly_weak = _plan(2.0)                      # no props, more expensive
    out = _prune([cheap_strong, costly_weak])
    assert list(out.values()) == [cheap_strong]


def test_costlier_plan_with_extra_props_survives():
    cheap_weak = _plan(1.0)
    costly_strong = _plan(2.0, partitions=[("k",)])
    out = _prune([cheap_weak, costly_strong])
    assert set(out.values()) == {cheap_weak, costly_strong}


def test_same_props_keeps_cheapest():
    a = _plan(2.0, partitions=[("k",)])
    b = _plan(1.0, partitions=[("k",)])
    out = _prune([a, b])
    assert list(out.values()) == [b]


def test_equal_cost_tie_dominance():
    # equal cost, one strictly better props vector: the weaker entry goes
    strong = _plan(1.0, partitions=[("k",)], sort=("k",))
    weak = _plan(1.0, partitions=[("k",)])
    out = _prune([weak, strong])
    assert list(out.values()) == [strong]
    out = _prune([strong, weak])                  # order-insensitive
    assert list(out.values()) == [strong]


def test_equal_cost_incomparable_props_both_survive():
    a = _plan(1.0, partitions=[("k",)])
    b = _plan(1.0, sort=("j",))
    out = _prune([a, b])
    assert set(out.values()) == {a, b}


def test_dominates_semantics():
    p = Props(partitions=frozenset({frozenset({"a"})}), sort=("a", "b"))
    q = Props(partitions=frozenset(), sort=("a",))
    assert p.dominates(q)          # superset partitions, sort prefix
    assert not q.dominates(p)
    assert p.dominates(p)          # reflexive
    r = Props(partitions=frozenset({frozenset({"c"})}), sort=())
    assert not p.dominates(r) and not r.dominates(p)   # incomparable


def test_prune_never_drops_overall_cheapest():
    """Property test (seeded, no hypothesis dependency): for random candidate
    sets, the cheapest input plan always survives, every surviving plan is
    non-dominated, and every dropped plan has a cheaper-or-equal dominator
    among the survivors."""
    rng = np.random.default_rng(42)
    attrs = ["a", "b", "c"]
    for _ in range(300):
        cands = []
        for _ in range(int(rng.integers(1, 14))):
            parts = [tuple(np.array(attrs)[rng.random(3) < 0.5]) or ("a",)
                     for _ in range(int(rng.integers(0, 3)))]
            sort = tuple(np.array(attrs)[:int(rng.integers(0, 4))])
            cands.append(_plan(float(rng.integers(1, 6)),
                               partitions=[p for p in parts if p],
                               sort=sort))
        out = _prune(cands)
        survivors = list(out.values())
        best_in = min(c.total_cost.total for c in cands)
        assert min(s.total_cost.total for s in survivors) == best_in
        for s in survivors:
            assert not any(
                o.props.dominates(s.props) and o.props != s.props
                and o.total_cost.total <= s.total_cost.total
                for o in survivors)
        for c in cands:
            if all(s is not c for s in survivors):
                assert any(
                    s.props.dominates(c.props)
                    and s.total_cost.total <= c.total_cost.total
                    for s in survivors), "dropped plan has no dominator"
