# NOTE: no XLA_FLAGS / device-count overrides here — smoke tests and benches
# must see the real (single) device.  Only launch/dryrun.py (and the
# dedicated subprocess tests) force 512 host devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
