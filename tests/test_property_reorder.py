"""Property-based safety test (paper Sec. 5 'safety'): every plan the
optimizer enumerates for a RANDOM flow of random black-box UDFs must produce
the same result multiset as the original plan, for random input data.

UDFs are generated as closures (modify / filter / add-attribute / reduce);
the jaxpr analyzer derives their properties — nothing about their semantics
is told to the optimizer.
"""

import numpy as np
import pytest

# optional dependency: skip cleanly (instead of failing collection)
# in environments without hypothesis
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import executor, flow as F
from repro.core.enumeration import enum_alternatives_alg1, enumerate_plans
from repro.core.record import Schema, batch_from_dict

FIELDS = ("A", "B", "C", "D")
SCHEMA = Schema.of(**{f: np.int64 for f in FIELDS})


def _modify(target, reads, mult, off):
    def udf(ir, out):
        val = ir.get(target) * 0
        for r in reads:
            val = val + ir.get(r)
        out.emit(ir.copy().set(target, val * mult + off))

    udf.__name__ = f"mod_{target}"
    return udf


def _filter(reads, mod, keep):
    def udf(ir, out):
        val = None
        for r in reads:
            val = ir.get(r) if val is None else val + ir.get(r)
        out.emit(ir.copy(), where=(val % mod) == keep)

    udf.__name__ = f"filt_{'_'.join(reads)}"
    return udf


def _adder(name, reads):
    def udf(ir, out):
        val = None
        for r in reads:
            val = ir.get(r) if val is None else val + ir.get(r)
        out.emit(ir.copy().set(name, val * 2))

    udf.__name__ = f"add_{name}"
    return udf


def _reducer(agg_field):
    def udf(g, out):
        out.emit(g.keys().set(f"sum_{agg_field}", g.sum(agg_field))
                 .set(f"max_{agg_field}", g.max(agg_field)))

    udf.__name__ = f"red_{agg_field}"
    return udf


@st.composite
def unary_flow(draw):
    ops = []
    n_ops = draw(st.integers(2, 5))
    live = list(FIELDS)
    n_added = 0
    for i in range(n_ops):
        kind = draw(st.sampled_from(["modify", "filter", "add", "reduce"]))
        if kind == "modify":
            target = draw(st.sampled_from(live))
            reads = draw(st.lists(st.sampled_from(live), min_size=0,
                                  max_size=2, unique=True))
            ops.append(("map", _modify(target, tuple(reads),
                                       draw(st.integers(1, 3)),
                                       draw(st.integers(-2, 2)))))
        elif kind == "filter":
            reads = draw(st.lists(st.sampled_from(live), min_size=1,
                                  max_size=2, unique=True))
            ops.append(("map", _filter(tuple(reads),
                                       draw(st.integers(2, 4)),
                                       draw(st.integers(0, 1)))))
        elif kind == "add":
            reads = draw(st.lists(st.sampled_from(live), min_size=1,
                                  max_size=2, unique=True))
            name = f"X{n_added}"
            n_added += 1
            ops.append(("map", _adder(name, tuple(reads))))
            live.append(name)
        else:
            key = draw(st.lists(st.sampled_from(live), min_size=1,
                                max_size=2, unique=True))
            agg = draw(st.sampled_from(live))
            ops.append(("reduce", tuple(key), _reducer(agg)))
            live = list(key) + [f"sum_{agg}", f"max_{agg}"]
    return ops


def _build(ops):
    node = F.source("I", SCHEMA)
    for i, op in enumerate(ops):
        if op[0] == "map":
            node = F.map_(node, op[1], name=f"{op[1].__name__}#{i}",
                          mode="jaxpr")
        else:
            node = F.reduce_(node, list(op[1]), op[2],
                             name=f"{op[2].__name__}#{i}", mode="jaxpr")
    return node


@settings(max_examples=20, deadline=None)
@given(ops=unary_flow(), seed=st.integers(0, 2**31))
def test_all_enumerated_plans_equivalent(ops, seed):
    try:
        root = _build(ops)
    except ValueError:
        return  # generated op referenced a dropped field — invalid flow
    rng = np.random.default_rng(seed)
    data = batch_from_dict({f: rng.integers(-5, 6, 40) for f in FIELDS})
    ref = executor.execute(root, {"I": data})
    plans = enumerate_plans(root, max_plans=2000)
    assert any(p.canonical() == root.canonical() for p in plans)
    for p in plans:
        got = executor.execute(p, {"I": data})
        assert got.equivalent(ref), (
            "reordered plan diverges:\n" + p.pretty() + "\nvs\n"
            + root.pretty())


@settings(max_examples=10, deadline=None)
@given(ops=unary_flow())
def test_algorithm1_matches_closure_on_unary_flows(ops):
    try:
        root = _build(ops)
    except ValueError:
        return
    alg1 = {p.canonical() for p in enum_alternatives_alg1(root)}
    closure = {p.canonical() for p in enumerate_plans(root)}
    # Algorithm 1 explores exchanges of neighbours top-down; the closure is
    # its fixpoint completion — on unary chains they must agree.
    assert alg1 == closure


@settings(max_examples=15, deadline=None)
@given(ops=unary_flow(), seed=st.integers(0, 2**31))
def test_masked_executor_matches_eager_on_random_flows(ops, seed):
    from repro.core.masked import run_flow_jit

    try:
        root = _build(ops)
    except ValueError:
        return
    rng = np.random.default_rng(seed)
    data = batch_from_dict({f: rng.integers(0, 6, 32) for f in FIELDS})
    ref = executor.execute(root, {"I": data})
    got = run_flow_jit(root, {"I": data})
    assert got.equivalent(ref)
