"""Property-based safety test (paper Sec. 5 'safety'): every plan the
optimizer enumerates for a RANDOM flow of random black-box UDFs must produce
the same result multiset as the original plan, for random input data.

Two generators drive this file:

* `flowgen.random_flow` — a seeded, dependency-free generator of tree-shaped
  flows (Map/Reduce/Match/Cross/CoGroup over random schemas) whose
  differential harness asserts every plan in the rewrite closure — split
  Reduces included — is BIT-identical to the unoptimized eager execution;
  these tests are tier-1 (no optional dependencies);
* a hypothesis strategy for unary chains (skipped when hypothesis is not
  installed), kept for shrinking-quality counterexamples.

UDFs are generated as closures; the SCA analyzers derive their properties —
nothing about their semantics is told to the optimizer.
"""

import numpy as np
import pytest

import flowgen

from repro.core import executor, flow as F
from repro.core.enumeration import enum_alternatives_alg1, enumerate_plans
from repro.core.record import Schema, batch_from_dict

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - optional dependency
    st = None

FIELDS = ("A", "B", "C", "D")
SCHEMA = Schema.of(**{f: np.int64 for f in FIELDS})


# ---------------------------------------------------------------------------
# Seeded tree-flow differential harness (tier-1, no optional deps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("seed", range(18))
def test_flowgen_closure_bit_identical(seed):
    """Every plan in the rewrite closure of a random tree flow — including
    combiner/merge splits — produces the bit-identical row multiset."""
    root, make_bindings = flowgen.random_flow(seed)
    flowgen.assert_closure_identical(root, make_bindings(seed + 1000),
                                     max_plans=2500)


def test_flowgen_exercises_split_reduces():
    """The generator must actually cover the split-Reduce rewrite (a harness
    that never generates a decomposable Reduce would vacuously pass)."""
    n_split = 0
    for seed in range(18):
        root, _ = flowgen.random_flow(seed)
        n_split += sum(".pre" in p.canonical()
                       for p in enumerate_plans(root, max_plans=2500))
    assert n_split >= 10


@pytest.mark.parametrize("seed", [0, 2, 6])
def test_flowgen_masked_matches_eager(seed):
    """Masked/jit execution of generated tree flows (joins, cogroups, splits
    included) agrees with the eager reference."""
    from repro.core.masked import run_flow_jit
    from repro.core.operators import ReduceOp

    root, make_bindings = flowgen.random_flow(seed)
    b = make_bindings(seed + 77)
    ref = executor.execute(root, b)
    assert run_flow_jit(root, b).equivalent(ref, atol=1e-6)
    # also check one split variant under jit when the flow admits one
    for p in enumerate_plans(root, max_plans=2500):
        if any(isinstance(n, ReduceOp) and n.combiner for n in p.iter_nodes()):
            assert run_flow_jit(p, b).equivalent(ref, atol=1e-6)
            break


# ---------------------------------------------------------------------------
# Adaptive serving differential harness (DESIGN.md §9): adversarial hints +
# drifting per-batch distributions, bit-identical across every plan swap
# ---------------------------------------------------------------------------
def test_flowgen_adaptive_serve_bit_identical_and_replans():
    """Random flows with every hint perturbed by up to 100x (underestimates
    included) served through an adaptive CompiledPlan over a workload whose
    distributions shift mid-serve: every batch — across calibration swaps
    and truncation re-runs — must be bit-identical to the eager reference
    (asserted per batch inside the harness).  The summed swap count guards
    against vacuity: a workload that never drifts past the trigger would
    pass the identity check without exercising a single re-plan."""
    total = 0
    for seed in (0, 1, 2, 4):
        root, make_bindings = flowgen.random_flow(seed)
        adv = flowgen.adversarial_hints(root, seed + 500)
        total += flowgen.assert_adaptive_identical(adv, make_bindings, seed)
    assert total >= 3


def test_adversarial_hints_seeded_and_semantics_preserving():
    root, _ = flowgen.random_flow(3)
    a1 = flowgen.adversarial_hints(root, 42)
    a2 = flowgen.adversarial_hints(root, 42)
    b = flowgen.adversarial_hints(root, 43)
    h1 = [n.hints for n in a1.iter_nodes() if hasattr(n, "hints")]
    assert h1 == [n.hints for n in a2.iter_nodes() if hasattr(n, "hints")]
    assert h1 != [n.hints for n in b.iter_nodes() if hasattr(n, "hints")]
    # pk_side (an execution-semantic hint) is never perturbed
    for orig, adv in zip(root.iter_nodes(), a1.iter_nodes()):
        if hasattr(orig, "hints"):
            assert adv.hints.pk_side == orig.hints.pk_side
    # the perturbation changes only hints, never the answer
    _, make_bindings = flowgen.random_flow(3)
    data = make_bindings(99)
    assert flowgen.canonical_rows(executor.execute(a1, data)) == \
        flowgen.canonical_rows(executor.execute(root, data))


# ---------------------------------------------------------------------------
# Hypothesis unary-chain strategy (optional dependency)
# ---------------------------------------------------------------------------
def _modify(target, reads, mult, off):
    def udf(ir, out):
        val = ir.get(target) * 0
        for r in reads:
            val = val + ir.get(r)
        out.emit(ir.copy().set(target, val * mult + off))

    udf.__name__ = f"mod_{target}"
    return udf


def _filter(reads, mod, keep):
    def udf(ir, out):
        val = None
        for r in reads:
            val = ir.get(r) if val is None else val + ir.get(r)
        out.emit(ir.copy(), where=(val % mod) == keep)

    udf.__name__ = f"filt_{'_'.join(reads)}"
    return udf


def _adder(name, reads):
    def udf(ir, out):
        val = None
        for r in reads:
            val = ir.get(r) if val is None else val + ir.get(r)
        out.emit(ir.copy().set(name, val * 2))

    udf.__name__ = f"add_{name}"
    return udf


def _reducer(agg_field):
    def udf(g, out):
        out.emit(g.keys().set(f"sum_{agg_field}", g.sum(agg_field))
                 .set(f"max_{agg_field}", g.max(agg_field)))

    udf.__name__ = f"red_{agg_field}"
    return udf


def _build(ops):
    node = F.source("I", SCHEMA)
    for i, op in enumerate(ops):
        if op[0] == "map":
            node = F.map_(node, op[1], name=f"{op[1].__name__}#{i}",
                          mode="jaxpr")
        else:
            node = F.reduce_(node, list(op[1]), op[2],
                             name=f"{op[2].__name__}#{i}", mode="jaxpr")
    return node


if st is not None:
    @st.composite
    def unary_flow(draw):
        ops = []
        n_ops = draw(st.integers(2, 5))
        live = list(FIELDS)
        n_added = 0
        for i in range(n_ops):
            kind = draw(st.sampled_from(["modify", "filter", "add", "reduce"]))
            if kind == "modify":
                target = draw(st.sampled_from(live))
                reads = draw(st.lists(st.sampled_from(live), min_size=0,
                                      max_size=2, unique=True))
                ops.append(("map", _modify(target, tuple(reads),
                                           draw(st.integers(1, 3)),
                                           draw(st.integers(-2, 2)))))
            elif kind == "filter":
                reads = draw(st.lists(st.sampled_from(live), min_size=1,
                                      max_size=2, unique=True))
                ops.append(("map", _filter(tuple(reads),
                                           draw(st.integers(2, 4)),
                                           draw(st.integers(0, 1)))))
            elif kind == "add":
                reads = draw(st.lists(st.sampled_from(live), min_size=1,
                                      max_size=2, unique=True))
                name = f"X{n_added}"
                n_added += 1
                ops.append(("map", _adder(name, tuple(reads))))
                live.append(name)
            else:
                key = draw(st.lists(st.sampled_from(live), min_size=1,
                                    max_size=2, unique=True))
                agg = draw(st.sampled_from(live))
                ops.append(("reduce", tuple(key), _reducer(agg)))
                live = list(key) + [f"sum_{agg}", f"max_{agg}"]
        return ops

    @settings(max_examples=20, deadline=None)
    @given(ops=unary_flow(), seed=st.integers(0, 2**31))
    def test_all_enumerated_plans_equivalent(ops, seed):
        try:
            root = _build(ops)
        except ValueError:
            return  # generated op referenced a dropped field — invalid flow
        rng = np.random.default_rng(seed)
        data = batch_from_dict({f: rng.integers(-5, 6, 40) for f in FIELDS})
        ref = executor.execute(root, {"I": data})
        plans = enumerate_plans(root, max_plans=2000)
        assert any(p.canonical() == root.canonical() for p in plans)
        for p in plans:
            got = executor.execute(p, {"I": data})
            assert got.equivalent(ref), (
                "reordered plan diverges:\n" + p.pretty() + "\nvs\n"
                + root.pretty())

    @settings(max_examples=10, deadline=None)
    @given(ops=unary_flow())
    def test_algorithm1_matches_closure_on_unary_flows(ops):
        try:
            root = _build(ops)
        except ValueError:
            return
        alg1 = {p.canonical() for p in enum_alternatives_alg1(root)}
        # Algorithm 1 explores exchanges of neighbours top-down; the closure
        # is its fixpoint completion — on unary chains they must agree on
        # the PURE REORDERING space (aggregation splits are a rewrite family
        # Algorithm 1 does not know about, so they are excluded here).
        closure = {p.canonical()
                   for p in enumerate_plans(root, split_reduces=False)}
        assert alg1 == closure

    @settings(max_examples=15, deadline=None)
    @given(ops=unary_flow(), seed=st.integers(0, 2**31))
    def test_masked_executor_matches_eager_on_random_flows(ops, seed):
        from repro.core.masked import run_flow_jit

        try:
            root = _build(ops)
        except ValueError:
            return
        rng = np.random.default_rng(seed)
        data = batch_from_dict({f: rng.integers(0, 6, 32) for f in FIELDS})
        ref = executor.execute(root, {"I": data})
        got = run_flow_jit(root, {"I": data})
        assert got.equivalent(ref)
