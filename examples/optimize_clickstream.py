"""The paper's flagship non-relational rewrite, reproduced interactively:
the selective 'Filter Logged-In Sessions' join pushed below two black-box
Reduce operators (Figs. 4 & 7) — an optimization 'no other system performs'.

    PYTHONPATH=src python examples/optimize_clickstream.py
"""

import time

from repro.configs import flows
from repro.core import executor
from repro.core.optimizer import optimize
from repro.core.physical import Ctx


def main():
    root, bindings = flows.clickstream()
    print("implemented flow:")
    print(root.pretty())

    res = optimize(root, Ctx(dop=32), include_commutes=False,
                   prune=False)  # figures need the full cost spectrum
    print(f"\n{res.num_plans} valid reordered plans "
          f"(enumerated in {res.enumeration_s * 1e3:.1f} ms):")
    for rp in res.ranked:
        mark = " <- join below both Reduces" if (
            rp.order().index("FilterLoggedIn")
            < rp.order().index("FilterBuySessions")) else ""
        print(f"  {rp.cost:.3e}s  {rp.order()}{mark}")

    print("\nbest physical plan:")
    print(res.best.plan.pretty())

    b = bindings(50_000, seed=0)
    for rp in (res.ranked[0], res.ranked[-1]):
        t0 = time.perf_counter()
        out = executor.execute(rp.flow, b)
        dt = time.perf_counter() - t0
        print(f"\n{rp.order()}\n  -> {out.num_valid()} rows in {dt:.3f}s")


if __name__ == "__main__":
    main()
