"""Multi-tenant dataflow serving: two tenants, one warm executable.

Two tenants register the SAME logical flow (built independently — what
matters is the commute-invariant `semantic_key`, not object identity).  The
engine routes both into one plan group, coalesces their queued requests
into shared device batches (each request's rows tagged with its ordinal so
groups and joins never mix tenants), and serves them on a single warm
jitted executable — then de-multiplexes per-request results back to each
caller.  The cache stats at the end show the whole mixed workload ran on a
handful of traces.

    PYTHONPATH=src python examples/serve_dataflow.py
"""

import numpy as np

from repro.core import flow as F
from repro.core.operators import Hints
from repro.core.record import Schema, batch_from_dict
from repro.serve.dataflow import DataflowEngine, ServeConfig


# one black-box flow, built twice (once per tenant) --------------------------
def sessionize(ir, out):               # keep purchases
    out.emit(ir.copy(), where=ir.get("action") == 1)


def spend(g, out):                     # total spend per user
    out.emit(g.first().set("amount", g.sum("amount")))


def build_flow():
    src = F.source("events", Schema.of(user=np.int64, action=np.int64,
                                       amount=np.float32),
                   num_records=100_000)
    kept = F.map_(src, sessionize, name="Purchases",
                  hints=Hints(selectivity=0.3))
    return F.reduce_(kept, ("user",), spend, name="SpendPerUser",
                     hints=Hints(distinct_keys=64))


def make_batch(seed, n=4096):
    rng = np.random.default_rng(seed)
    return {"events": batch_from_dict({
        "user": rng.integers(0, 64, n).astype(np.int64),
        "action": rng.integers(0, 3, n).astype(np.int64),
        "amount": rng.random(n).astype(np.float32)})}


def main():
    eng = DataflowEngine(ServeConfig(max_coalesce=8))
    eng.register("alice", build_flow())
    eng.register("bob", build_flow())   # same semantics: same plan group

    # open-loop submissions from both tenants, then one pump sweep
    reqs = [eng.submit(tenant, make_batch(seed=100 * t + i))
            for i in range(8) for t, tenant in enumerate(("alice", "bob"))]
    eng.drain()                         # or eng.start() for a pump thread

    for r in reqs[:4]:
        top = r.result().to_numpy().compact()
        print(f"  {r.tenant}: {top.capacity} users, "
              f"latency {r.latency * 1e3:.1f}ms")

    print("\n== one plan group, shared warm executables")
    for tenant in ("alice", "bob"):
        print(f"  {tenant}: {eng.tenant_stats(tenant)}")
    s = eng.stats()
    print(f"  groups={s['groups']} coalesced={s['coalesced_requests']} "
          f"solo={s['solo_requests']}")
    print(f"  cache : {s['cache']}")


if __name__ == "__main__":
    main()
