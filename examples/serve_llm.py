"""Batched serving example: slot-based engine over prefill + decode steps.

Uses the qwen3-0.6b architecture at reduced width (this container is CPU);
the full config serves on the 16x16 mesh via the dry-run-verified shardings.

    PYTHONPATH=src python examples/serve_llm.py
"""

import numpy as np
import jax

from repro.configs import get_config
from repro.models import make_model
from repro.serve.engine import Engine, Request


def main():
    cfg = get_config("qwen3-0.6b", reduced=True)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    engine = Engine(model, params, batch_slots=4, max_seq=128)

    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab, size=n).astype(np.int32),
                max_new_tokens=16,
                temperature=0.0 if i % 2 == 0 else 0.8)
        for i, n in enumerate([5, 9, 3, 12, 7, 4])
    ]
    engine.generate(requests)
    for i, r in enumerate(requests):
        kind = "greedy" if r.temperature == 0 else f"T={r.temperature}"
        print(f"req{i} ({kind}, prompt={len(r.prompt)} toks) "
              f"-> {r.out_tokens}")


if __name__ == "__main__":
    main()
