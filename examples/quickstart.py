"""Quickstart: the paper's Sec. 3 example, end to end.

Build a PACT flow of black-box UDFs, let static code analysis derive the
read/write sets, enumerate every safe reordering, price them on the TPU
fabric model, and execute the best plan — eager, as a compiled pipeline
(`optimize(...).compile().run(bindings)`: the serving path, one warm jitted
executable over many request batches), and data-parallel under shard_map.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import executor, flow as F
from repro.core.distributed import execute_distributed
from repro.core.operators import Hints
from repro.core.optimizer import optimize
from repro.core.physical import Ctx
from repro.core.record import Schema, batch_from_dict


# --- the paper's three black-box UDFs (Sec. 3) -----------------------------
def f1(ir, out):                       # B := |B|
    out.emit(ir.copy().set("B", abs(ir.get("B"))))


def f2(ir, out):                       # keep rows with A >= 0
    out.emit(ir.copy(), where=ir.get("A") >= 0)


def f3(ir, out):                       # A := A + B
    out.emit(ir.copy().set("A", ir.get("A") + ir.get("B")))


def main():
    src = F.source("I", Schema.of(A=np.int64, B=np.int64), num_records=10**7)
    plan = F.map_(F.map_(F.map_(src, f1, name="Map1"),
                         f2, name="Map2", hints=Hints(selectivity=0.5)),
                  f3, name="Map3")

    print("== derived properties (nobody told the optimizer what the UDFs do)")
    for node in plan.iter_nodes():
        if hasattr(node, "props"):
            p = node.props
            print(f"  {node.name}: R={sorted(p.reads)} W={sorted(p.writes)} "
                  f"card={p.card.value} via {p.source}")

    res = optimize(plan, Ctx(dop=8), prune=False)  # price all, for the demo
    print("\n== enumerated plans (Map1<->Map2 commute; Map3 conflicts on A,B)")
    for rp in res.ranked:
        print(f"  {rp.cost:.3e}s  {rp.order()}")
    print(res.summary())

    data = batch_from_dict({
        "A": np.array([2, -2, 5, -1]), "B": np.array([-3, -3, 4, 7])})
    bindings = {"I": data}
    best = res.best.flow
    print("\n== executing the best plan three ways")
    print("  eager      :", executor.execute(best, bindings).sorted_tuples())
    compiled = res.compile()  # fused + jitted once; warm for every batch
    print("  pipeline   :", compiled.run(bindings).sorted_tuples())
    print("  distributed:", execute_distributed(
        res.best.plan, bindings).sorted_tuples())

    batch2 = {"I": batch_from_dict({
        "A": np.array([1, -4, 3, 9]), "B": np.array([2, -8, -6, 0])})}
    print("\n== serving pattern: fresh batch, warm executable (no retrace)")
    print("  pipeline   :", compiled.run(batch2).sorted_tuples())
    print("  cache      :", compiled.cache_stats())

    # Many concurrent callers?  The multi-tenant engine wraps this same
    # warm-executable loop with semantic-key routing, request coalescing and
    # per-tenant drift isolation (DESIGN.md §11):
    from repro.serve.dataflow import DataflowEngine

    eng = DataflowEngine()
    eng.register("tenant-a", plan)     # same key -> same plan group,
    eng.register("tenant-b", plan)     # shared warm executable
    reqs = [eng.submit(t, bindings) for t in ("tenant-a", "tenant-b")]
    eng.drain()                        # or eng.start() for a pump thread
    print("\n== multi-tenant serving (examples/serve_dataflow.py for more)")
    for r in reqs:
        print(f"  {r.tenant:9s}:", r.result().sorted_tuples())
    print("  engine     :", eng.stats()["cache"])


if __name__ == "__main__":
    main()
