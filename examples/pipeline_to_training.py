"""End-to-end training driver: the paper's optimized data-flow plane feeding
a from-scratch LM train loop with checkpointing and fault tolerance.

The input pipeline is a PACT flow (quality filter -> dedup Reduce -> domain
join) that `repro.core.optimizer` reorders before execution; batches are a
pure function of (seed, step), so the Supervisor's crash-restart replays the
stream exactly.

    PYTHONPATH=src python examples/pipeline_to_training.py --steps 200
"""

import argparse

import jax

from repro.data.pipeline import TokenPipeline
from repro.models import ModelConfig, make_model
from repro.train.fault import Supervisor
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="e2e-lm", family="dense", n_layers=args.layers,
        d_model=args.d_model, n_heads=args.d_model // 64,
        n_kv_heads=max(1, args.d_model // 128), d_ff=args.d_model * 4,
        vocab=4096, dtype="float32")
    model = make_model(cfg)
    print(f"model: {model.param_count() / 1e6:.1f}M params")

    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)
    print("input pipeline plan (chosen by the data-flow optimizer):")
    print(pipe.optimized.summary())

    params = model.init(jax.random.key(0))
    opt = init_opt_state(params)
    tcfg = TrainConfig(opt=AdamWConfig(
        lr=3e-4, warmup_steps=20, total_steps=args.steps))
    step_fn = jax.jit(make_train_step(model, tcfg))

    sup = Supervisor(ckpt_dir=args.ckpt_dir, ckpt_every=50)
    state = {"params": params, "opt": opt, "step": 0}
    state, watchdog = sup.run(state=state, train_step=step_fn,
                              batch_fn=pipe, num_steps=args.steps,
                              log_every=20)
    print(f"done at step {state['step']}; stragglers observed: "
          f"{len(watchdog.events)}")


if __name__ == "__main__":
    main()
